"""Trip-count-aware collective accounting from post-SPMD HLO.

Collectives inserted by GSPMD inside scan bodies appear in the HLO while-body
computations — a flat sum over the module counts them ONCE even though they
execute trip-count times.  This parser:

  1. splits the HLO text into computations,
  2. finds every `while(...)` instruction with its body=/condition= refs,
  3. extracts the trip count from the condition computation (jax scans lower
     to a counted loop: `compare(iter, constant(N)), direction=LT`),
  4. recursively totals collective bytes: total(c) = direct(c) +
     Σ_while trip(w) × total(body(w)).

Byte convention per op kind (ring-algorithm lower bounds, n = group size):
  all-gather:        result bytes (full gathered tensor lands per device)
  reduce-scatter:    input bytes (shard leaves per step; ≈input over ring)
  all-reduce:        2 × result bytes (reduce-scatter + all-gather phases)
  all-to-all:        result bytes
  collective-permute: result bytes
"""
from __future__ import annotations

import re
from typing import Dict, Optional

_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%([^\s(]+)\s*\(")
_WHILE_RE = re.compile(
    r"while\(.*?\)[^\n]*?condition=%?([\w.\-]+)[^\n]*?body=%?([\w.\-]+)"
)
_WHILE_RE2 = re.compile(
    r"while\(.*?\)[^\n]*?body=%?([\w.\-]+)[^\n]*?condition=%?([\w.\-]+)"
)
_CONST_RE = re.compile(r"constant\((\d+)\)")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)

KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
         "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _split_computations(hlo: str) -> Dict[str, str]:
    """Computation defs are unindented `%name (params...) -> type {` lines
    (params may contain nested parens for tuple types); bodies are indented;
    a bare `}` closes them."""
    comps: Dict[str, list] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if cur is None:
            if line.startswith((" ", "\t")) or not stripped.endswith("{"):
                continue
            m = _COMP_HEADER.match(stripped)
            if m:
                cur = m.group(1)
                comps[cur] = []
        else:
            if stripped == "}":
                cur = None
            else:
                comps[cur].append(line)
    return {k: "\n".join(v) for k, v in comps.items()}


def _direct_collectives(body: str) -> Dict[str, float]:
    out = {k: 0.0 for k in KINDS}
    counts = {k: 0 for k in KINDS}
    for line in body.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(shape_str)
        if kind == "all-reduce":
            b *= 2  # reduce-scatter + all-gather phases
        elif kind == "reduce-scatter":
            # result is the scattered shard; ring moves ~input = result × n.
            # n is not in the shape; stay with result bytes (lower bound).
            pass
        out[kind] += b
        counts[kind] += 1
    return {"bytes": out, "counts": counts}


def _whiles_in(body: str):
    for m in _WHILE_RE.finditer(body):
        yield m.group(1), m.group(2)  # cond, body
    for m in _WHILE_RE2.finditer(body):
        yield m.group(2), m.group(1)


def _trip_count(cond_body: str) -> float:
    consts = [int(c) for c in _CONST_RE.findall(cond_body)]
    return float(max(consts)) if consts else 1.0


def collective_bytes_with_trips(hlo: str) -> Dict[str, object]:
    comps = _split_computations(hlo)
    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w.\-]+)", line)
            if m:
                entry = m.group(1)
            break
    if entry is None or entry not in comps:
        # fall back: treat the whole text as one computation
        d = _direct_collectives(hlo)
        total = sum(d["bytes"].values())
        return {"total": total, "per_kind": d["bytes"], "counts": d["counts"],
                "trip_corrected": False}

    memo: Dict[str, Dict[str, float]] = {}

    def total_of(name: str, depth=0) -> Dict[str, float]:
        if name in memo:
            return memo[name]
        if name not in comps or depth > 50:
            return {k: 0.0 for k in KINDS}
        body = comps[name]
        d = _direct_collectives(body)["bytes"]
        for cond, wbody in _whiles_in(body):
            trips = _trip_count(comps.get(cond, ""))
            sub = total_of(wbody, depth + 1)
            for k in KINDS:
                d[k] += trips * sub[k]
        memo[name] = d
        return d

    # also descend into non-while called computations (fusions/calls) from
    # the entry: conservative approach — calls other than while bodies are
    # executed once; include any computation that contains collectives and
    # is referenced via to_apply/calls from the entry closure.
    per = total_of(entry)
    counts = _direct_collectives(hlo)["counts"]  # raw op counts (uncorrected)
    return {
        "total": sum(per.values()),
        "per_kind": per,
        "counts": counts,
        "trip_corrected": True,
    }
