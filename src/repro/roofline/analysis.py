"""Roofline analysis from compiled dry-run artifacts.

Three terms, each a lower-bound execution time in seconds (TPU v5e):

  compute    = HLO_FLOPs_total        / (chips * 197e12)   [bf16 MXU]
  memory     = HLO_bytes_total        / (chips * 819e9)    [HBM]
  collective = collective_bytes_total / (chips * 50e9)     [per-link ICI]

``cost_analysis()`` reports per-device numbers for the SPMD module; totals
are per-device * chips, so the division by chips cancels — we compute the
terms directly from the per-device numbers and say so in EXPERIMENTS.md.

collective_bytes is NOT in cost_analysis: we parse the post-SPMD HLO and sum
operand sizes of every all-gather / all-reduce / reduce-scatter / all-to-all
/ collective-permute.  Bytes counted are the per-device shard bytes moved by
the op (operand size for AG/AR/A2A/CP; ×(1-1/n)≈1 ring-transfer convention),
a standard lower-bound convention for ring algorithms.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict



@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str = "tpu_v5e"
    peak_flops: float = 197e12  # bf16 per chip
    hbm_bw: float = 819e9  # bytes/s per chip
    ici_bw: float = 50e9  # bytes/s per link
    hbm_bytes: float = 16 * 2 ** 30  # 16 GiB per chip


HW = Hardware()

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)
# e.g.:  %x = f32[8,128]{1,0} all-gather(f32[1,128]{1,0} %y), ...
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)


def _shape_bytes(shape_str: str) -> int:
    """Bytes of 'f32[8,128]' or a tuple '(f32[..], bf16[..])'."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum result-shape bytes of every collective op in the HLO, by kind.

    '-start' ops are counted; their '-done' twins are skipped (the shape
    appears on both).  Result-shape is the right operand-size convention for
    all-gather (full gathered bytes land per device) and all-to-all; for
    all-reduce and reduce-scatter it equals/bounds the per-device shard
    moved per ring pass.
    """
    out = {k: 0.0 for k in _COLLECTIVE_KINDS}
    counts = {k: 0 for k in _COLLECTIVE_KINDS}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if f"{m.group(2)}-done(" in line:
            continue
        shape_str, kind = m.group(1), m.group(2)
        out[kind] += _shape_bytes(shape_str)
        counts[kind] += 1
    out_total = sum(out.values())
    return {"total": out_total, "per_kind": out, "counts": counts}


def roofline_terms(
    flops_per_device: float,
    bytes_per_device: float,
    collective_bytes_per_device: float,
    hw: Hardware = HW,
) -> Dict[str, float]:
    """All inputs are per-device (the SPMD module's numbers)."""
    compute = flops_per_device / hw.peak_flops
    memory = bytes_per_device / hw.hbm_bw
    collective = collective_bytes_per_device / hw.ici_bw
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": collective}
    dom = max(terms, key=terms.get)
    bound = max(compute, memory, collective)
    terms["dominant"] = dom
    terms["bound_s"] = bound
    # fraction of the bound that is useful MXU work — the roofline fraction
    terms["compute_fraction_of_bound"] = compute / bound if bound > 0 else 0.0
    return terms


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N·D for train (fwd+bwd), 2·N·D per decoded/prefilled
    token — with N = active params for MoE."""
    counts = cfg.param_count()
    n_active = counts["active"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        if cfg.family == "encdec":
            tokens = shape.global_batch * (shape.seq_len // cfg.dec_ratio)
            # encoder tokens ride at 2·N_enc — folded into active count approx
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        if cfg.family == "encdec":
            tokens = shape.global_batch * (shape.seq_len + shape.seq_len // cfg.dec_ratio)
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def roofline_report(cell: dict, hw: Hardware = HW) -> dict:
    """Assemble the EXPERIMENTS.md row from one dry-run cell record.

    Prefers the trip-count-aware jaxpr costs (global / chips) over raw XLA
    cost_analysis (which counts loop bodies once); collective bytes come
    from the while-trip-corrected HLO parse, divided per device is already
    implicit (post-SPMD HLO is the per-device program)."""
    chips = cell.get("chips", 1)
    jx = cell.get("jaxpr_cost")
    if jx:
        flops = jx["flops_per_device"]
        byts = jx["bytes_per_device"]
    else:
        flops = cell["cost_analysis"].get("flops", 0.0)
        byts = cell["cost_analysis"].get("bytes accessed", 0.0)
    coll = cell["collectives"]["total"]
    terms = roofline_terms(flops, byts, coll, hw)
    mf = cell.get("model_flops", 0.0)
    terms["model_flops"] = mf
    terms["useful_ratio"] = (mf / chips) / flops if flops else 0.0
    terms["mfu_bound"] = (mf / chips / hw.peak_flops) / terms["bound_s"] \
        if terms["bound_s"] else 0.0
    return terms
