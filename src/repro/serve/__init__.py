from repro.serve.bits import bits_to_tokens, tokens_to_bits
from repro.serve.engine import ServeEngine

__all__ = ["ServeEngine", "bits_to_tokens", "tokens_to_bits"]
