from repro.serve.engine import ServeEngine
from repro.serve.viterbi_head import ViterbiHead

__all__ = ["ServeEngine", "ViterbiHead"]
