"""Token <-> bitstream packing for the serving pipeline.

The serving scenario treats LM output as a bitstream to be channel-coded:
tokens are unpacked MSB-first into bits, pushed through a codec from
``repro.decode`` / ``repro.siso``, and re-packed after decoding.
"""
from __future__ import annotations

import jax.numpy as jnp


def tokens_to_bits(tokens: jnp.ndarray, bits_per_token: int) -> jnp.ndarray:
    """(B, T) int32 -> (B, T*bits) {0,1} MSB-first — LM output as a bitstream."""
    shifts = jnp.arange(bits_per_token - 1, -1, -1)
    bits = (tokens[..., None] >> shifts) & 1
    return bits.reshape(tokens.shape[0], -1).astype(jnp.int32)


def bits_to_tokens(bits: jnp.ndarray, bits_per_token: int) -> jnp.ndarray:
    B, n = bits.shape
    bits = bits.reshape(B, n // bits_per_token, bits_per_token)
    weights = 1 << jnp.arange(bits_per_token - 1, -1, -1)
    return jnp.einsum("btk,k->bt", bits, weights).astype(jnp.int32)
