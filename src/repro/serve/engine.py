"""Batched serving engine: prefill + jitted decode loop with greedy /
temperature sampling and per-request stop handling.

The engine owns the cache pytree and step functions; the decode step is
jitted once per (batch, cache_len) bucket.  On a mesh, caches are sharded by
the model's cache rules (batch over data, cache seq over model for
flash-decode) — the same shardings the dry-run proves out.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class ServeEngine:
    model: "object"
    params: "object"
    max_len: int
    mesh: Optional[object] = None
    temperature: float = 0.0
    eos: int = 0

    def __post_init__(self):
        m = self.model

        def _prefill(params, batch, caches):
            return m.prefill(params, batch, caches, mesh=self.mesh)

        def _decode(params, tokens, positions, caches):
            return m.decode_step(params, tokens, positions, caches, mesh=self.mesh)

        if self.mesh is not None:
            with self.mesh:
                self._prefill = jax.jit(_prefill)
                self._decode = jax.jit(_decode, donate_argnums=(3,))
        else:
            self._prefill = jax.jit(_prefill)
            self._decode = jax.jit(_decode, donate_argnums=(3,))

    def _sample(self, logits, key):
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits.astype(jnp.float32) / self.temperature, axis=-1
        ).astype(jnp.int32)

    def generate(
        self,
        prompts: jnp.ndarray,  # (B, S_prompt) int32
        max_new_tokens: int,
        seed: int = 0,
    ) -> Dict[str, jnp.ndarray]:
        """Greedy/temperature generation for a batch of equal-length prompts."""
        B, S_p = prompts.shape
        caches = self.model.init_cache(B, self.max_len)
        ctx = self.mesh if self.mesh is not None else _nullcontext()
        with ctx:
            logits, caches = self._prefill(self.params, {"tokens": prompts}, caches)
            key = jax.random.PRNGKey(seed)
            tok = self._sample(logits, key)[:, None]
            out = [tok]
            positions = jnp.full((B,), S_p, jnp.int32)
            done = jnp.zeros((B,), bool)
            for _ in range(max_new_tokens - 1):
                key, sub = jax.random.split(key)
                logits, caches = self._decode(self.params, tok, positions, caches)
                nxt = self._sample(logits, sub)[:, None]
                done = done | (tok[:, 0] == self.eos)
                nxt = jnp.where(done[:, None], self.eos, nxt)
                out.append(nxt)
                tok = nxt
                positions = positions + 1
        return {"tokens": jnp.concatenate(out, axis=1), "done": done}


class _nullcontext:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False
