"""DEPRECATED Viterbi serving head — a thin shim over ``repro.decode``.

The string ``mode`` dispatch this module used to own is gone: every decoder
backend now lives behind ``repro.decode``'s DecoderRegistry with one
normalized ``decode(spec, bm_tables, *, ctx)`` signature, and
``repro.decode.plan_decode`` auto-selects a backend from the problem shape.
``ViterbiHead(mode=...)`` maps the mode string to a registry lookup
(``repro.decode.get_decoder(mode)``) and warns once per process.

Migrate::

    # old
    head = ViterbiHead(code=code, mode="fused", soft=True)
    bits, metric = head.decode(rx)

    # new
    from repro.decode import CodecSpec, DecodeRequest, decode
    spec = CodecSpec(code=code, metric="soft")
    res = decode(DecodeRequest(spec, received=rx))   # planner picks a backend
    res.info_bits, res.path_metric

The token<->bit helpers (``tokens_to_bits`` / ``bits_to_tokens``) are not
deprecated and stay here.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Tuple

import jax.numpy as jnp

from repro.core.trellis import CODE_K3_STD, ConvCode
from repro.decode import CodecSpec, DecodeContext, plan_decode

_DEPRECATION_WARNED = False


def _warn_once() -> None:
    global _DEPRECATION_WARNED
    if not _DEPRECATION_WARNED:
        _DEPRECATION_WARNED = True
        warnings.warn(
            "ViterbiHead is deprecated: use repro.decode (CodecSpec + "
            "plan_decode/decode); mode strings map to registry backends.",
            DeprecationWarning,
            stacklevel=3,
        )


@dataclasses.dataclass
class ViterbiHead:
    """Deprecated shim: ``mode`` is a DecoderRegistry name, everything else
    is folded into a CodecSpec/DecodeContext pair (see ``spec``/``ctx``)."""

    code: ConvCode = CODE_K3_STD
    mode: Optional[str] = None  # registry backend name; None -> planner auto-select
    soft: bool = False
    mesh: Optional[object] = None
    chunk: int = 64
    stream_depth: Optional[int] = None  # traceback depth for 'streaming' (default 5K)
    terminated: bool = True

    def __post_init__(self):
        _warn_once()

    @property
    def spec(self) -> CodecSpec:
        return CodecSpec(
            code=self.code,
            metric="soft" if self.soft else "hard",
            terminated=self.terminated,
        )

    @property
    def ctx(self) -> DecodeContext:
        return DecodeContext(
            mesh=self.mesh,
            chunk=self.chunk,
            stream_depth=self.stream_depth,
            streaming=self.mode == "streaming",
        )

    # ------------------------- encode side ------------------------- #

    def encode_bits(self, bits: jnp.ndarray) -> jnp.ndarray:
        """(B, T) info bits -> (B, T + n_flush, n_out) coded bits."""
        return self.spec.encode(bits)

    def channel(self, key, coded_bits, *, flip_prob=0.0, snr_db=None):
        """Hard (BSC) or soft (BPSK+AWGN) channel simulation."""
        if snr_db is not None:
            from repro.core.channel import awgn, bpsk_modulate

            return awgn(key, bpsk_modulate(coded_bits), snr_db)
        from repro.core.channel import bsc

        return bsc(key, coded_bits, flip_prob)

    # ------------------------- decode side ------------------------- #

    def branch_metrics(self, received) -> jnp.ndarray:
        return self.spec.branch_metrics(received)

    def decode(self, received) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """received: (B, T, n_out) hard bits or soft values.
        Returns (info_bits, path_metric (B,)); flush bits are stripped only
        for terminated specs."""
        bm = self.branch_metrics(received)
        result = self._plan(bm.shape).execute(bm)
        return result.info_bits, result.path_metric

    def decode_from_metrics(self, bm_tables) -> Tuple[jnp.ndarray, jnp.ndarray]:
        result = self._plan(bm_tables.shape).execute(bm_tables)
        return result.bits, result.path_metric

    def _plan(self, shape):
        return plan_decode(self.spec, shape, backend=self.mode, ctx=self.ctx)

    # --------------------- end-to-end convenience --------------------- #

    def roundtrip(self, key, bits, *, flip_prob=0.02, snr_db=None):
        """encode -> channel -> decode; returns (decoded, ber, exact)."""
        coded = self.encode_bits(bits)
        rx = self.channel(key, coded, flip_prob=flip_prob, snr_db=snr_db)
        dec, _ = self.decode(rx)
        ber = (dec != bits).mean()
        return dec, ber, bool((dec == bits).all())


def tokens_to_bits(tokens: jnp.ndarray, bits_per_token: int) -> jnp.ndarray:
    """(B, T) int32 -> (B, T*bits) {0,1} MSB-first — LM output as a bitstream."""
    shifts = jnp.arange(bits_per_token - 1, -1, -1)
    bits = (tokens[..., None] >> shifts) & 1
    return bits.reshape(tokens.shape[0], -1).astype(jnp.int32)


def bits_to_tokens(bits: jnp.ndarray, bits_per_token: int) -> jnp.ndarray:
    B, n = bits.shape
    bits = bits.reshape(B, n // bits_per_token, bits_per_token)
    weights = 1 << jnp.arange(bits_per_token - 1, -1, -1)
    return jnp.einsum("btk,k->bt", bits, weights).astype(jnp.int32)
