"""Viterbi serving head — the paper's technique as a first-class serving
feature.

Decodes convolutionally-encoded bit streams (the paper's "10^15 bits/day of
digital TV" use case) behind one object:

  encode-side:  bits -> conv encode -> (optional channel sim)
  decode-side:  received bits/LLRs -> branch metrics -> fused Viterbi
                (Pallas Texpand kernels) -> info bits

Decoder selection:
  'fused'        kernels.viterbi_decode_fused (VMEM-resident Pallas scan)
  'sequential'   core.viterbi_decode (jnp lax.scan reference)
  'parallel'     core.viterbi_decode_parallel ((min,+) associative scan)
  'seqparallel'  parallel.collectives.viterbi_decode_seqparallel
                 (shard_map across the 'model' mesh axis — for long streams)
  'streaming'    stream.viterbi_decode_windowed (truncated-traceback sliding
                 window over the chunked Pallas scan — O(depth) memory, the
                 online path; see stream/ for sessions and the continuous-
                 batching scheduler behind long-lived connections)

An LM can be piped straight into the head: generate token bits, encode,
push through a noisy channel, decode, and verify — see
examples/serve_viterbi.py.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.channel import (
    awgn,
    bpsk_modulate,
    bsc,
    hard_branch_metrics,
    soft_branch_metrics,
)
from repro.core.encoder import encode
from repro.core.trellis import CODE_K3_STD, ConvCode
from repro.core.viterbi import viterbi_decode, viterbi_decode_parallel
from repro.kernels.ops import viterbi_decode_fused


@dataclasses.dataclass
class ViterbiHead:
    code: ConvCode = CODE_K3_STD
    mode: str = "fused"  # fused | sequential | parallel | seqparallel | streaming
    soft: bool = False
    mesh: Optional[object] = None
    chunk: int = 64
    stream_depth: Optional[int] = None  # traceback depth for 'streaming' (default 5K)

    # ------------------------- encode side ------------------------- #

    def encode_bits(self, bits: jnp.ndarray) -> jnp.ndarray:
        """(B, T) info bits -> (B, T+K-1, n_out) coded bits (terminated)."""
        return encode(self.code, bits, terminate=True)

    def channel(self, key, coded_bits, *, flip_prob=0.0, snr_db=None):
        """Hard (BSC) or soft (BPSK+AWGN) channel simulation."""
        if snr_db is not None:
            return awgn(key, bpsk_modulate(coded_bits), snr_db)
        return bsc(key, coded_bits, flip_prob)

    # ------------------------- decode side ------------------------- #

    def branch_metrics(self, received) -> jnp.ndarray:
        if self.soft:
            return soft_branch_metrics(self.code, received)
        return hard_branch_metrics(self.code, received)

    def decode(self, received) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """received: (B, T, n_out) hard bits or soft values.
        Returns (info_bits (B, T-(K-1)), path_metric (B,))."""
        bm = self.branch_metrics(received)
        bits, metric = self.decode_from_metrics(bm)
        K = self.code.constraint
        return bits[:, : bits.shape[1] - (K - 1)], metric  # drop flush bits

    def decode_from_metrics(self, bm_tables) -> Tuple[jnp.ndarray, jnp.ndarray]:
        if self.mode == "fused":
            return viterbi_decode_fused(self.code, bm_tables)
        if self.mode == "sequential":
            return viterbi_decode(self.code, bm_tables)
        if self.mode == "parallel":
            return viterbi_decode_parallel(self.code, bm_tables, chunk=self.chunk)
        if self.mode == "seqparallel":
            from repro.parallel.collectives import viterbi_decode_seqparallel

            assert self.mesh is not None, "seqparallel needs a mesh"
            return viterbi_decode_seqparallel(self.code, bm_tables, self.mesh)
        if self.mode == "streaming":
            from repro.stream.window import viterbi_decode_windowed

            return viterbi_decode_windowed(
                self.code, bm_tables, depth=self.stream_depth, chunk=self.chunk
            )
        raise KeyError(self.mode)

    # --------------------- end-to-end convenience --------------------- #

    def roundtrip(self, key, bits, *, flip_prob=0.02, snr_db=None):
        """encode -> channel -> decode; returns (decoded, ber, exact)."""
        coded = self.encode_bits(bits)
        rx = self.channel(key, coded, flip_prob=flip_prob, snr_db=snr_db)
        dec, _ = self.decode(rx)
        ber = (dec != bits).mean()
        return dec, ber, bool((dec == bits).all())


def tokens_to_bits(tokens: jnp.ndarray, bits_per_token: int) -> jnp.ndarray:
    """(B, T) int32 -> (B, T*bits) {0,1} MSB-first — LM output as a bitstream."""
    shifts = jnp.arange(bits_per_token - 1, -1, -1)
    bits = (tokens[..., None] >> shifts) & 1
    return bits.reshape(tokens.shape[0], -1).astype(jnp.int32)


def bits_to_tokens(bits: jnp.ndarray, bits_per_token: int) -> jnp.ndarray:
    B, n = bits.shape
    bits = bits.reshape(B, n // bits_per_token, bits_per_token)
    weights = 1 << jnp.arange(bits_per_token - 1, -1, -1)
    return jnp.einsum("btk,k->bt", bits, weights).astype(jnp.int32)
