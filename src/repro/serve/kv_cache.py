"""KV-cache bookkeeping for the serving engine.

The cache *layouts* are owned by the models (models/transformer.cache_specs);
this module adds serving-side management: length buckets (compile-once per
bucket), batched slot assignment for continuous batching, and memory
accounting used by the launcher to pick bucket sizes.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.models.common import _is_spec


DEFAULT_BUCKETS = (1024, 4096, 16384, 32768, 131072, 524288)


def pick_bucket(prompt_len: int, max_new: int,
                buckets: Tuple[int, ...] = DEFAULT_BUCKETS) -> int:
    need = prompt_len + max_new
    i = bisect.bisect_left(buckets, need)
    if i == len(buckets):
        raise ValueError(f"request needs {need} tokens > max bucket {buckets[-1]}")
    return buckets[i]


def cache_bytes(model, B: int, S: int) -> int:
    """Total cache bytes for a (batch, bucket) — for admission control."""
    specs = model.cache_specs(B, S)
    total = 0
    for leaf in jax.tree_util.tree_leaves(specs, is_leaf=_is_spec):
        total += int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
    return total


@dataclasses.dataclass
class SlotAllocator:
    """Continuous batching: fixed B decode slots, requests claim/release."""

    n_slots: int
    free: Optional[List[int]] = None
    active: Dict[int, str] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.free is None:
            self.free = list(range(self.n_slots))

    def claim(self, request_id: str) -> Optional[int]:
        if not self.free:
            return None
        slot = self.free.pop()
        self.active[slot] = request_id
        return slot

    def release(self, slot: int) -> None:
        self.active.pop(slot, None)
        self.free.append(slot)

    def utilization(self) -> float:
        return len(self.active) / self.n_slots
