"""Training launcher.

On real hardware: one process per host (jax.distributed.initialize picks up
the pod topology), production mesh from launch/mesh.py, sharded data by
process_index, async checkpoints to shared storage, crash -> restore ->
resume.  On this CPU container the same code path runs a reduced config
end-to-end (examples/train_lm.py drives it).

  python -m repro.launch.train --arch qwen3_4b --smoke --steps 20
"""
from __future__ import annotations

import argparse
import json

from repro.obs.log import get_logger

log = get_logger("launch.train")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=0)
    ap.add_argument("--global-batch", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--mesh", default="none", choices=("none", "single", "multi", "host"))
    ap.add_argument("--distributed", action="store_true",
                    help="call jax.distributed.initialize() (multi-host pods)")
    args = ap.parse_args()

    if args.distributed:
        import jax

        jax.distributed.initialize()

    import jax

    from repro.configs.base import SHAPES, get_arch, get_smoke_arch
    from repro.data.pipeline import make_data_iter
    from repro.launch.mesh import make_production_mesh, smoke_mesh
    from repro.models.model_zoo import build
    from repro.train.train_loop import train

    bundle = get_smoke_arch(args.arch) if args.smoke else get_arch(args.arch)
    model = build(bundle)

    import dataclasses

    shape = SHAPES["train_4k"]
    if args.seq_len:
        shape = dataclasses.replace(shape, seq_len=args.seq_len)
    if args.global_batch:
        shape = dataclasses.replace(shape, global_batch=args.global_batch)
    if args.smoke and not args.seq_len:
        shape = dataclasses.replace(shape, seq_len=128, global_batch=4)

    mesh = None
    if args.mesh == "single":
        mesh = make_production_mesh()
    elif args.mesh == "multi":
        mesh = make_production_mesh(multi_pod=True)
    elif args.mesh == "host":
        mesh = smoke_mesh()

    data = make_data_iter(model, shape)
    report = train(
        model, data, steps=args.steps, lr=args.lr, warmup=args.warmup,
        mesh=mesh,
        checkpoint_dir=args.checkpoint_dir or None,
        checkpoint_every=args.checkpoint_every,
    )
    last = report["history"][-1] if report["history"] else {}
    log.info(json.dumps({
        "arch": model.cfg.name, "steps": report["final_step"],
        "restarts": report["restarts"],
        "straggler_events": len(report["straggler_events"]),
        "final_metrics": {k: v for k, v in last.items() if k != "step"},
    }, indent=1))


if __name__ == "__main__":
    main()
