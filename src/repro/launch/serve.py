"""Serving launcher: batched generation + the Viterbi decode path.

  python -m repro.launch.serve --arch qwen2_5_3b --smoke --tokens 32
  python -m repro.launch.serve --viterbi --bits 256 --batch 64 --backend fused
  python -m repro.launch.serve --viterbi --backend auto   # planner picks
"""
from __future__ import annotations

import argparse
import json
import time

from repro.obs.log import get_logger

log = get_logger("launch.serve")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_5_3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    # Viterbi decode path
    ap.add_argument("--viterbi", action="store_true")
    ap.add_argument("--bits", type=int, default=256)
    ap.add_argument("--backend", "--mode", dest="backend", default="auto",
                    help="registry backend name, or 'auto' for the planner")
    ap.add_argument("--flip-prob", type=float, default=0.02)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    if args.viterbi:
        from repro.configs.paper_viterbi import DECODE_SPEC
        from repro.decode import DecodeRequest, decode

        spec = DECODE_SPEC
        backend = None if args.backend == "auto" else args.backend
        key = jax.random.PRNGKey(0)
        bits = jax.random.bernoulli(key, 0.5, (args.batch, args.bits)).astype(jnp.int32)
        coded = spec.encode(bits)
        rx = spec.channel(jax.random.PRNGKey(1), coded, flip_prob=args.flip_prob)
        t0 = time.perf_counter()
        res = decode(DecodeRequest(spec, received=rx), backend=backend)
        jax.block_until_ready(res.bits)
        dt = time.perf_counter() - t0
        ber = float((res.info_bits != bits).mean())
        log.info(res.plan.explain(costs=True))
        log.info(json.dumps({
            "backend": res.plan.backend, "batch": args.batch, "bits": args.bits,
            "ber": ber, "exact": bool((res.info_bits == bits).all()),
            "throughput_bits_per_s": args.batch * args.bits / dt,
        }, indent=1))
        return

    from repro.configs.base import get_arch, get_smoke_arch
    from repro.models.model_zoo import build
    from repro.serve.engine import ServeEngine

    bundle = get_smoke_arch(args.arch) if args.smoke else get_arch(args.arch)
    model = build(bundle)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params,
                         max_len=args.prompt_len + args.tokens,
                         temperature=args.temperature)
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, model.cfg.vocab)
    t0 = time.perf_counter()
    out = engine.generate(prompts, args.tokens)
    dt = time.perf_counter() - t0
    log.info(json.dumps({
        "arch": model.cfg.name, "batch": args.batch,
        "new_tokens": int(out["tokens"].shape[1]),
        "tokens_per_s": args.batch * out["tokens"].shape[1] / dt,
        "sample": out["tokens"][0, :8].tolist(),
    }, indent=1))


if __name__ == "__main__":
    main()
