"""Serving launcher: batched generation + the Viterbi decode head.

  python -m repro.launch.serve --arch qwen2_5_3b --smoke --tokens 32
  python -m repro.launch.serve --viterbi --bits 256 --batch 64 --mode fused
"""
from __future__ import annotations

import argparse
import json
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_5_3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    # Viterbi head
    ap.add_argument("--viterbi", action="store_true")
    ap.add_argument("--bits", type=int, default=256)
    ap.add_argument("--mode", default="fused",
                    choices=("fused", "sequential", "parallel"))
    ap.add_argument("--flip-prob", type=float, default=0.02)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    if args.viterbi:
        from repro.serve.viterbi_head import ViterbiHead

        head = ViterbiHead(mode=args.mode)
        key = jax.random.PRNGKey(0)
        bits = jax.random.bernoulli(key, 0.5, (args.batch, args.bits)).astype(jnp.int32)
        t0 = time.perf_counter()
        dec, ber, exact = head.roundtrip(jax.random.PRNGKey(1), bits,
                                         flip_prob=args.flip_prob)
        dt = time.perf_counter() - t0
        print(json.dumps({
            "mode": args.mode, "batch": args.batch, "bits": args.bits,
            "ber": float(ber), "exact": exact,
            "throughput_bits_per_s": args.batch * args.bits / dt,
        }, indent=1))
        return

    from repro.configs.base import get_arch, get_smoke_arch
    from repro.models.model_zoo import build
    from repro.serve.engine import ServeEngine

    bundle = get_smoke_arch(args.arch) if args.smoke else get_arch(args.arch)
    model = build(bundle)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params,
                         max_len=args.prompt_len + args.tokens,
                         temperature=args.temperature)
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, model.cfg.vocab)
    t0 = time.perf_counter()
    out = engine.generate(prompts, args.tokens)
    dt = time.perf_counter() - t0
    print(json.dumps({
        "arch": model.cfg.name, "batch": args.batch,
        "new_tokens": int(out["tokens"].shape[1]),
        "tokens_per_s": args.batch * out["tokens"].shape[1] / dt,
        "sample": out["tokens"][0, :8].tolist(),
    }, indent=1))


if __name__ == "__main__":
    main()
