import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines, before ANY other import: jax locks the
#   device count on first backend init.  512 placeholder host devices let
#   jax.make_mesh build the production meshes.  This is set ONLY here —
#   smoke tests and benchmarks see the real single device.

"""Multi-pod dry-run: lower + compile every (architecture × input-shape ×
mesh) cell and extract memory / cost / collective evidence.

Per cell:
  with mesh:
      lowered  = jax.jit(step, in_shardings=..., out_shardings=...) \
                     .lower(**input_specs(arch, shape))
      compiled = lowered.compile()
      print(compiled.memory_analysis())   # proves it fits per-chip HBM
      print(compiled.cost_analysis())     # FLOPs / bytes for the roofline

Results land in benchmarks/results/dryrun/<cell>.json, consumed by
benchmarks/roofline_report.py and EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch qwen3_4b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--jobs 1]
"""
import argparse
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

from repro.obs.log import get_logger

RESULTS = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"

log = get_logger("launch.dryrun")


def _build_step(model, shape, mesh, overrides=None):
    """Returns (fn, kwargs of ShapeDtypeStructs-with-shardings)."""
    import jax

    from repro.models import common as cm
    from repro.parallel.sharding import shard_batch_tree
    from repro.train.optimizer import cosine_warmup, get_optimizer

    rules = overrides or None
    specs = model.input_specs(shape)

    def attach(tree, shardings):
        return jax.tree_util.tree_map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            tree, shardings)

    abs_params = attach(model.abstract_params(), model.param_shardings(mesh, rules))
    if shape.kind != "train":
        # serving runs on bf16 weights (standard practice): halves the
        # per-chip param footprint the decode/prefill cells must hold
        import jax.numpy as jnp

        abs_params = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape,
                jnp.bfloat16 if s.dtype == jnp.dtype(jnp.float32) else s.dtype,
                sharding=s.sharding),
            abs_params)

    if shape.kind == "train":
        from repro.train.train_loop import build_step_fn

        optimizer = get_optimizer(model.part.optimizer)
        lr_fn = cosine_warmup(3e-4, 100, 10000)
        opt_abs = optimizer.state_specs(model.param_specs)
        opt_abs_sds = cm.abstract(opt_abs)
        opt_sh = cm.shardings(opt_abs, mesh, model._rules(rules, for_opt=True))
        abs_opt = attach(opt_abs_sds, opt_sh)
        batch = attach(specs["batch"], shard_batch_tree(mesh, specs["batch"]))
        train_step = build_step_fn(model, optimizer, lr_fn, mesh, rules)

        kwargs = {
            "params": abs_params,
            "opt_state": abs_opt,
            "batch": batch,
            "step_idx": jax.ShapeDtypeStruct((), jax.numpy.int32),
        }
        # params/opt_state are donated (aliased in->out), as in the real
        # training loop: the update is in-place, not double-buffered
        return train_step, kwargs, ("params", "opt_state")

    if shape.kind == "prefill":
        B, S = shape.global_batch, shape.seq_len
        caches = attach(specs["caches"], model.cache_shardings(mesh, B, S, rules))
        batch = attach(specs["batch"], shard_batch_tree(mesh, specs["batch"]))

        def prefill_step(params, batch, caches):
            return model.prefill(params, batch, caches, mesh=mesh, rules=rules)

        return (prefill_step,
                {"params": abs_params, "batch": batch, "caches": caches},
                ("caches",))

    # decode
    B, S = shape.global_batch, shape.seq_len
    caches = attach(specs["caches"], model.cache_shardings(mesh, B, S, rules))
    toks = attach(
        {"tokens": specs["tokens"], "positions": specs["positions"]},
        shard_batch_tree(mesh, {"tokens": specs["tokens"],
                                "positions": specs["positions"]}))

    def serve_step(params, tokens, positions, caches):
        return model.decode_step(params, tokens, positions, caches,
                                 mesh=mesh, rules=rules)

    return (serve_step,
            {"params": abs_params, "tokens": toks["tokens"],
             "positions": toks["positions"], "caches": caches},
            ("caches",))


def run_cell(arch_id: str, shape_name: str, mesh_kind: str,
             overrides=None, tag: str = "", partition=None) -> dict:
    import dataclasses

    import jax

    from repro.configs.base import SHAPES, get_arch
    from repro.launch.mesh import make_production_mesh
    from repro.roofline.analysis import collective_bytes, model_flops
    from repro.roofline.hlo_loops import collective_bytes_with_trips
    from repro.roofline.jaxpr_cost import count_fn_costs

    bundle = get_arch(arch_id)
    if partition:  # perf-iteration knobs, e.g. '{"zero_stage": 1}'
        bundle = dataclasses.replace(
            bundle, partition=dataclasses.replace(bundle.partition, **partition))
    shape = SHAPES[shape_name]
    skip = bundle.skips(shape_name)
    if skip:
        return {"arch": arch_id, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": skip}

    from repro.models.model_zoo import build

    model = build(bundle)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = 1
    for v in dict(mesh.shape).values():
        chips *= v

    t0 = time.time()
    with mesh:
        fn, kwargs, donate = _build_step(model, shape, mesh, overrides)
        lowered = jax.jit(fn, donate_argnames=donate).lower(**kwargs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):  # older jax: one dict per computation
            cost = cost[0] if cost else {}
        log.info(str(mem))
        log.info(str({k: v for k, v in cost.items()
                      if k in ("flops", "bytes accessed")}))
        hlo = compiled.as_text()
        # trip-count-aware GLOBAL costs (XLA's cost_analysis counts loop
        # bodies once — see roofline/jaxpr_cost.py)
        jx = count_fn_costs(fn, **kwargs)
    coll_raw = collective_bytes(hlo)
    coll = collective_bytes_with_trips(hlo)

    mem_rec = {
        k: getattr(mem, k)
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes")
        if hasattr(mem, k)
    }
    cell = {
        "arch": arch_id, "shape": shape_name, "mesh": mesh_kind,
        "tag": tag, "status": "ok", "chips": chips,
        "mesh_shape": dict(mesh.shape),
        "step_kind": shape.kind,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": mem_rec,
        "cost_analysis": {k: cost[k] for k in ("flops", "bytes accessed")
                          if k in cost},
        "jaxpr_cost": {
            "flops_global": jx["flops"],
            "bytes_global": jx["bytes"],
            "input_bytes_global": jx.get("input_bytes", 0.0),
            "flops_per_device": jx["flops"] / chips,
            "bytes_per_device": jx["bytes"] / chips,
        },
        "collectives": coll,
        "collectives_raw_once": coll_raw,
        "model_flops": model_flops(model.cfg, shape),
        "hlo_sizes": {"n_lines": hlo.count("\n")},
    }
    return cell


ARCHS = (
    "qwen3_moe_30b_a3b", "deepseek_v2_lite_16b", "xlstm_350m", "qwen1_5_110b",
    "qwen3_4b", "gemma3_12b", "qwen2_5_3b", "internvl2_26b",
    "seamless_m4t_large_v2", "jamba_v0_1_52b",
)
SHAPE_NAMES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=("single", "multi", "both"))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=1)
    ap.add_argument("--tag", default="")
    ap.add_argument("--override", default="",
                    help="JSON dict of sharding-rule overrides (perf knobs)")
    ap.add_argument("--partition", default="",
                    help="JSON dict of PartitionConfig overrides (perf knobs)")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    RESULTS.mkdir(parents=True, exist_ok=True)

    if args.all:
        # orchestrate one subprocess per cell (device count is locked per
        # process; separate processes also bound compile-memory blowups)
        meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)
        jobs = []
        for arch in ARCHS:
            for shp in SHAPE_NAMES:
                for mk in meshes:
                    out = RESULTS / f"{arch}--{shp}--{mk}{args.tag}.json"
                    if out.exists() and not args.force:
                        continue
                    jobs.append((arch, shp, mk))
        log.info("cells to run", n=len(jobs))
        running = []
        while jobs or running:
            while jobs and len(running) < args.jobs:
                arch, shp, mk = jobs.pop(0)
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shp, "--mesh", mk,
                       "--tag", args.tag]
                if args.override:
                    cmd += ["--override", args.override]
                log.info("LAUNCH", arch=arch, shape=shp, mesh=mk)
                running.append(((arch, shp, mk), subprocess.Popen(cmd)))
            done = [(c, p) for c, p in running if p.poll() is not None]
            running = [(c, p) for c, p in running if p.poll() is None]
            for c, p in done:
                arch, shp, mk = c
                if p.returncode == 0:
                    log.info("DONE", arch=arch, shape=shp, mesh=mk)
                else:
                    log.error("FAIL", arch=arch, shape=shp, mesh=mk,
                              returncode=p.returncode)
            time.sleep(2)
        return

    overrides = json.loads(args.override) if args.override else None
    partition = json.loads(args.partition) if args.partition else None
    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)
    for mk in meshes:
        out = RESULTS / f"{args.arch}--{args.shape}--{mk}{args.tag}.json"
        try:
            cell = run_cell(args.arch, args.shape, mk, overrides, args.tag,
                            partition)
        except Exception as e:  # record the failure — failures are bugs
            cell = {"arch": args.arch, "shape": args.shape, "mesh": mk,
                    "tag": args.tag, "status": "error",
                    "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:]}
        out.write_text(json.dumps(cell, indent=1, default=float))
        log.info(json.dumps({k: cell.get(k) for k in
                             ("arch", "shape", "mesh", "status")}, indent=None))
        if cell["status"] == "error":
            sys.exit(1)


if __name__ == "__main__":
    main()
