"""Production mesh definitions.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (device count locks on first backend init — the dry-run
sets XLA_FLAGS before any jax import).

Mesh shapes (TPU v5e pods):
  single-pod: (16, 16)    axes (data, model)   = 256 chips
  multi-pod:  (2, 16, 16) axes (pod, data, model) = 512 chips; the 'pod'
              axis is data-parallel over DCN (gradient all-reduce crosses
              pods once per step; everything else stays inside a pod).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes))


def smoke_mesh():
    """Whatever devices exist, as a 1D 'data' mesh (tests / CPU runs)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))
