"""Jaxpr-level contract lint for the decode hot paths.

The paper's thesis is that the Viterbi inner loop is a small, precisely
specified contract (the ACS "custom instruction") whose guarantees must not
erode as the system around it grows.  Our jax_pallas equivalents of those
guarantees — no host callbacks inside a jitted hot path, zero cross-shard
collectives in the sharded tick, every path metric staying in the declared
``metric_dtype``, a bounded number of outputs per launch — were previously
enforced only by scattered hand-written spy tests.  This module checks them
mechanically: walk the closed jaxpr of a registered hot path (the same
equation-walking idiom as ``roofline.jaxpr_cost``, which *counts* where this
module *asserts*) and report every equation that violates the declared
:class:`Contract` as a structured :class:`ContractViolation` naming the
primitive and its source line.

Checked properties:

  host callbacks   ``pure_callback`` / ``io_callback`` / ``debug_callback``
                   (and the legacy host_callback bridges) force a host
                   round-trip per launch — forbidden on every hot path.
  collectives      ``psum`` / ``ppermute`` / ``all_gather`` / … are only
                   legal where a contract explicitly allowlists them
                   (seqparallel's seam gather); the sharded streaming tick
                   allows NONE — its speedup depends on a comms-free body.
  dtype policy     no float64 anywhere (a silent x64 leak doubles VMEM and
                   halves lane width), and no floating dtype outside the
                   contract's ``metric_dtype`` + ``extra_float_dtypes`` (the
                   hook the quantized-metric ROADMAP item will use: an int8
                   ACS ships with a contract whose metric_dtype is int8).
  output count     ``max_outputs`` bounds the top-level results a hot path
                   may emit — each output is a device buffer the host may
                   later sync on.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, List, Optional, Sequence, Tuple

import jax
import numpy as np

#: primitives that call back into Python from inside a compiled computation
HOST_CALLBACK_PRIMS = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
    "outside_call", "host_callback_call",
})

#: named-axis communication primitives (anything that moves data between
#: shards); a hot path must allowlist every one it legitimately uses.
#: shard_map's replication-rewrite emits ``psum2``/``pbroadcast2`` variants —
#: ``_canonical_prim`` folds those onto the public names so contracts are
#: written (and allowlisted) in user-facing terms.
COLLECTIVE_PRIMS = frozenset({
    "psum", "pmin", "pmax", "pbroadcast", "ppermute", "pgather",
    "all_gather", "all_to_all", "reduce_scatter", "psum_scatter",
})

_PRIM_ALIASES = {"psum2": "psum", "pbroadcast2": "pbroadcast"}


def _canonical_prim(name: str) -> str:
    return _PRIM_ALIASES.get(name, name)


@dataclasses.dataclass(frozen=True)
class Contract:
    """Declarative hot-path contract, checked equation-by-equation.

    Attributes:
      name: contract label used in reports (usually the backend name).
      metric_dtype: the one floating dtype the path may compute in; every
        float-dtyped value outside this (plus ``extra_float_dtypes``) is a
        ``dtype`` violation.  float64 is always a violation of its own kind.
      extra_float_dtypes: additional tolerated float dtypes (e.g. a bf16
        accumulator a future quantized backend declares explicitly).
      allowed_collectives: collective primitives this path may emit —
        empty for every comms-free path.
      allow_host_callbacks: opt-out for debug-only paths; no shipped
        contract sets it.
      max_outputs: bound on the top-level jaxpr outputs (None = unbounded).
    """

    name: str
    metric_dtype: str = "float32"
    extra_float_dtypes: Tuple[str, ...] = ()
    allowed_collectives: frozenset = frozenset()
    allow_host_callbacks: bool = False
    max_outputs: Optional[int] = None
    notes: str = ""

    def allowed_floats(self) -> frozenset:
        return frozenset((self.metric_dtype,) + self.extra_float_dtypes)


@dataclasses.dataclass(frozen=True)
class ContractViolation:
    """One broken guarantee: which contract, what kind, where."""

    contract: str
    kind: str        # "host-callback" | "collective" | "float64" | "dtype" | "outputs"
    primitive: str
    detail: str
    where: str       # best-effort "file.py:line (function)" of the equation
    path: str        # nesting of enclosing primitives, e.g. "pjit/shard_map/scan"

    def __str__(self) -> str:
        loc = f" at {self.where}" if self.where else ""
        ctx = f" [{self.path}]" if self.path else ""
        return (
            f"{self.contract}: {self.kind} violation — {self.detail} "
            f"(primitive {self.primitive!r}){loc}{ctx}"
        )


def _source_of(eqn) -> str:
    """Best-effort source line for an equation (private API, so guarded)."""
    try:
        from jax._src import source_info_util

        return source_info_util.summarize(eqn.source_info)
    except Exception:
        return ""


def _sub_jaxprs(value) -> Iterable:
    """Yield every (Closed)Jaxpr reachable from one eqn param value."""
    from jax.core import Jaxpr

    if isinstance(value, Jaxpr):
        yield value
    elif hasattr(value, "jaxpr") and isinstance(getattr(value, "jaxpr"), Jaxpr):
        yield value.jaxpr
    elif isinstance(value, (tuple, list)):
        for item in value:
            yield from _sub_jaxprs(item)


def _eqn_dtypes(eqn):
    for v in list(eqn.invars) + list(eqn.outvars):
        aval = getattr(v, "aval", None)
        dt = getattr(aval, "dtype", None)
        if dt is not None:
            yield np.dtype(dt)


def check_jaxpr(
    jaxpr, contract: Contract, _path: Tuple[str, ...] = ()
) -> List[ContractViolation]:
    """Walk ``jaxpr`` (a Jaxpr or ClosedJaxpr) recursively — the same
    sub-jaxpr recursion as ``roofline.jaxpr_cost.count_jaxpr``, covering
    scan/while/cond bodies, pjit/remat calls, and shard_map — and collect
    every equation that breaks ``contract``."""
    inner = getattr(jaxpr, "jaxpr", jaxpr)
    out: List[ContractViolation] = []
    allowed_floats = contract.allowed_floats()
    for eqn in inner.eqns:
        name = _canonical_prim(eqn.primitive.name)
        if name in HOST_CALLBACK_PRIMS and not contract.allow_host_callbacks:
            out.append(ContractViolation(
                contract=contract.name, kind="host-callback", primitive=name,
                detail="host callback inside a compiled hot path",
                where=_source_of(eqn), path="/".join(_path),
            ))
        if name in COLLECTIVE_PRIMS and name not in contract.allowed_collectives:
            out.append(ContractViolation(
                contract=contract.name, kind="collective", primitive=name,
                detail="cross-shard collective outside the contract allowlist",
                where=_source_of(eqn), path="/".join(_path),
            ))
        seen = set()
        for dt in _eqn_dtypes(eqn):
            key = str(dt)
            if key in seen:
                continue
            seen.add(key)
            if key == "float64":
                out.append(ContractViolation(
                    contract=contract.name, kind="float64", primitive=name,
                    detail="float64 value leaked into the hot path",
                    where=_source_of(eqn), path="/".join(_path),
                ))
            elif (
                jax.dtypes.issubdtype(dt, np.floating)  # incl. bf16/float8
                and key not in allowed_floats
            ):
                out.append(ContractViolation(
                    contract=contract.name, kind="dtype", primitive=name,
                    detail=(
                        f"{key} value outside the declared metric dtype "
                        f"{contract.metric_dtype!r}"
                    ),
                    where=_source_of(eqn), path="/".join(_path),
                ))
        for param in eqn.params.values():
            for sub in _sub_jaxprs(param):
                out.extend(check_jaxpr(sub, contract, _path + (name,)))
    return out


def trace_contract(
    fn: Callable,
    args: Sequence,
    contract: Contract,
) -> Tuple["jax.core.ClosedJaxpr", List[ContractViolation]]:
    """Trace ``fn(*args)`` abstractly (args may be ShapeDtypeStructs) and
    check the resulting jaxpr against ``contract``.  Returns the closed
    jaxpr (so callers can report equation counts) and the violations."""
    closed = jax.make_jaxpr(fn)(*args)
    violations = check_jaxpr(closed, contract)
    n_out = len(closed.jaxpr.outvars)
    if contract.max_outputs is not None and n_out > contract.max_outputs:
        violations.append(ContractViolation(
            contract=contract.name, kind="outputs", primitive="<jaxpr>",
            detail=f"{n_out} outputs exceed the contract bound "
                   f"{contract.max_outputs}",
            where="", path="",
        ))
    return closed, violations
