"""Repo-rule AST linter: the codebase conventions ruff can't express.

Rules (RPR = "repro rule"):

  RPR001  no ``print()`` in ``src/`` — report through ``repro.obs.log`` so
          output is level-gated and silenceable in CI.
  RPR002  kernel call sites must route ``interpret`` through
          ``resolve_interpret``: passing a raw ``interpret=True/False``
          literal at a call site pins one kernel's mode independently of the
          rest of the decode, which is exactly the split-decode hazard the
          resolve-once policy exists to prevent.  (``interpret=None`` and
          forwarding a resolved variable are both fine.)
  RPR003  no host-sync idioms — ``np.asarray`` / ``np.array`` / ``float()``
          / ``.item()`` / ``.block_until_ready()`` / ``jax.device_get`` —
          inside the hot-path scopes (the per-tick device loop: all of
          ``stream/window.py``, the scheduler's ``step``/``_step_traced``,
          and every ``kernels/`` module).  The ONE sanctioned sync per
          scheduler tick (the committed-bits transfer) carries an inline
          ``repr-lint: allow[RPR003]`` comment pragma.
  RPR004  every ``@register_decoder`` name must appear in the decode-API
          equivalence grid (tests/test_decode_api.py EXPECTED_BACKENDS) and
          in golden BER coverage (a ``*_BACKENDS`` tuple or ``CODECS`` key
          in tests/test_golden_ber.py) — or carry an explicit, reasoned
          exemption in ``GOLDEN_BER_EXEMPT`` below.
  RPR005  every registry backend must declare its code family explicitly:
          ``capabilities=BackendCapabilities(family="...", ...)`` — the
          planner routes by family before any shape rule, so an implicit
          default is a silent wrong-algebra hazard when new families land.

Suppression: append ``# repr-lint: allow[RPRnnn]`` (comma-separate several
codes) to the flagged line, with a justification comment.  Pragmas are
deliberately line-scoped — a module-wide opt-out would defeat the point.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

#: rule code -> one-line description (the README table is generated from the
#: same text; keep them in sync)
RULES: Dict[str, str] = {
    "RPR001": "no print() in src/ — use repro.obs.log",
    "RPR002": "no raw interpret=True/False literals at call sites — "
              "route through resolve_interpret (pass None or a resolved "
              "variable)",
    "RPR003": "no host-sync idioms (np.asarray/np.array/float()/.item()/"
              ".block_until_ready()/jax.device_get) in hot-path scopes",
    "RPR004": "every @register_decoder name must ride the decode-API "
              "equivalence grid and golden BER coverage",
    "RPR005": "registry backends must declare BackendCapabilities.family "
              "explicitly",
}

#: registry names exempt from RPR004's golden-BER leg, each with the reason
#: (the equivalence-grid leg still applies to them).  An exemption is a
#: documented decision, not a hole: these names are quality-gated elsewhere.
GOLDEN_BER_EXEMPT: Dict[str, str] = {
    "seqparallel": "mesh-required; bit-exactness gated by the multidevice "
                   "differential leg (tests/multidevice)",
    "sharded_stream": "mesh-required; gated by the multidevice differential "
                      "+ resilience legs and the sharded golden-BER smoke",
    "bcjr": "SISO constituent: pinned to the brute-force oracle in "
            "tests/test_siso.py and exercised by the turbo golden sweep",
}

#: hot-path scopes for RPR003: (path suffix or directory prefix, function
#: names or None for the whole module).  This is the per-tick device loop —
#: broad enough to catch a new sync sneaking into a kernel wrapper, narrow
#: enough that host-side bookkeeping (ingest, snapshot, reports) stays free
#: to materialize arrays.
HOT_PATH_SCOPES: Tuple[Tuple[str, Optional[frozenset]], ...] = (
    ("repro/stream/window.py", None),
    ("repro/stream/scheduler.py", frozenset({"step", "_step_traced"})),
    ("repro/kernels/", None),
)

_PRAGMA_RE = re.compile(r"#\s*repr-lint:\s*allow\[([A-Z0-9,\s]+)\]")

#: attribute names whose call is a device->host sync idiom
_SYNC_ATTRS = frozenset({"item", "block_until_ready"})
_NP_SYNC_FUNCS = frozenset({"asarray", "array"})


@dataclasses.dataclass(frozen=True)
class LintViolation:
    rule: str
    path: str
    line: int
    col: int
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


def find_pragmas(source: str) -> Dict[int, Set[str]]:
    """{line number: {rule codes allowed on that line}}."""
    out: Dict[int, Set[str]] = {}
    for i, text in enumerate(source.splitlines(), start=1):
        m = _PRAGMA_RE.search(text)
        if m:
            out[i] = {c.strip() for c in m.group(1).split(",") if c.strip()}
    return out


def _is_name(node: ast.AST, name: str) -> bool:
    return isinstance(node, ast.Name) and node.id == name


def _np_attr(node: ast.AST, attrs: frozenset) -> Optional[str]:
    """'asarray' if node is np.asarray / numpy.asarray (etc.), else None."""
    if (
        isinstance(node, ast.Attribute)
        and node.attr in attrs
        and isinstance(node.value, ast.Name)
        and node.value.id in ("np", "numpy")
    ):
        return node.attr
    return None


class _FileLinter(ast.NodeVisitor):
    """Per-file rules: RPR001, RPR002, RPR003, RPR005."""

    def __init__(self, path: Path, rel: str, source: str, in_src: bool):
        self.rel = rel
        self.in_src = in_src
        self.pragmas = find_pragmas(source)
        self.violations: List[LintViolation] = []
        self._func_stack: List[str] = []
        posix = rel.replace("\\", "/")
        self._hot_funcs: Optional[frozenset] = None
        self._hot_module = False
        for scope, funcs in HOT_PATH_SCOPES:
            if posix.endswith(scope) or (scope.endswith("/") and scope in posix):
                if funcs is None:
                    self._hot_module = True
                else:
                    self._hot_funcs = funcs

    # ----------------------------------------------------------------- util

    def _flag(self, rule: str, node: ast.AST, message: str) -> None:
        line = getattr(node, "lineno", 0)
        if rule in self.pragmas.get(line, set()):
            return
        self.violations.append(LintViolation(
            rule=rule, path=self.rel, line=line,
            col=getattr(node, "col_offset", 0), message=message,
        ))

    def _in_hot_scope(self) -> bool:
        if self._hot_module:
            return True
        if self._hot_funcs is not None:
            return any(f in self._hot_funcs for f in self._func_stack)
        return False

    # -------------------------------------------------------------- visitors

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._func_stack.append(node.name)
        self.generic_visit(node)
        self._func_stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Call(self, node: ast.Call) -> None:
        if self.in_src:
            self._check_print(node)
            self._check_interpret_literal(node)
            self._check_register_decoder(node)
        if self._in_hot_scope():
            self._check_host_sync(node)
        self.generic_visit(node)

    # ---------------------------------------------------------------- rules

    def _check_print(self, node: ast.Call) -> None:
        if _is_name(node.func, "print"):
            self._flag("RPR001", node,
                       "print() in library code — use repro.obs.log")

    def _check_interpret_literal(self, node: ast.Call) -> None:
        for kw in node.keywords:
            if (
                kw.arg == "interpret"
                and isinstance(kw.value, ast.Constant)
                and kw.value.value in (True, False)
            ):
                self._flag("RPR002", node,
                           f"raw interpret={kw.value.value} literal — "
                           "resolve via resolve_interpret and pass the "
                           "variable (or None) instead")

    def _check_host_sync(self, node: ast.Call) -> None:
        np_fn = _np_attr(node.func, _NP_SYNC_FUNCS)
        if np_fn is not None:
            self._flag("RPR003", node,
                       f"np.{np_fn}() host sync in a hot-path scope")
            return
        if _is_name(node.func, "float") and node.args:
            self._flag("RPR003", node,
                       "float() host sync in a hot-path scope")
            return
        if isinstance(node.func, ast.Attribute):
            if node.func.attr in _SYNC_ATTRS:
                self._flag("RPR003", node,
                           f".{node.func.attr}() host sync in a hot-path "
                           "scope")
            elif (
                node.func.attr == "device_get"
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id == "jax"
            ):
                self._flag("RPR003", node,
                           "jax.device_get() host sync in a hot-path scope")

    def _check_register_decoder(self, node: ast.Call) -> None:
        if not _is_name(node.func, "register_decoder"):
            return
        caps = next(
            (kw.value for kw in node.keywords if kw.arg == "capabilities"),
            None,
        )
        if caps is None:
            self._flag("RPR005", node,
                       "register_decoder without capabilities= — declare "
                       "BackendCapabilities(family=...)")
            return
        if (isinstance(caps, ast.Call)
                and (_is_name(caps.func, "BackendCapabilities")
                     or (isinstance(caps.func, ast.Attribute)
                         and caps.func.attr == "BackendCapabilities"))
                and not any(kw.arg == "family" for kw in caps.keywords)):
            self._flag("RPR005", node,
                       "BackendCapabilities without an explicit "
                       "family= — the planner routes by family")
        # capabilities bound to a variable: out of static reach, skipped


def _iter_py_files(paths: Sequence[Path]) -> Iterable[Path]:
    for p in paths:
        if p.is_dir():
            yield from sorted(p.rglob("*.py"))
        elif p.suffix == ".py":
            yield p


def _repo_root(start: Path) -> Optional[Path]:
    cur = start.resolve()
    if cur.is_file():
        cur = cur.parent
    for cand in (cur, *cur.parents):
        if (cand / "pyproject.toml").exists():
            return cand
    return None


def registered_decoder_names(src_root: Path) -> Dict[str, Tuple[str, int]]:
    """{backend name: (file, line)} for every register_decoder call site."""
    out: Dict[str, Tuple[str, int]] = {}
    for path in _iter_py_files([src_root]):
        try:
            tree = ast.parse(path.read_text())
        except SyntaxError:
            continue
        for node in ast.walk(tree):
            if (
                isinstance(node, ast.Call)
                and _is_name(node.func, "register_decoder")
                and node.args
                and isinstance(node.args[0], ast.Constant)
                and isinstance(node.args[0].value, str)
            ):
                out[node.args[0].value] = (str(path), node.lineno)
    return out


def _string_tuple_assigns(tree: ast.Module, suffix: str) -> Dict[str, List[str]]:
    """Module-level ``X_BACKENDS = ("a", "b", ...)`` style assignments."""
    out: Dict[str, List[str]] = {}
    for node in tree.body:
        if not isinstance(node, ast.Assign) or len(node.targets) != 1:
            continue
        tgt = node.targets[0]
        if not (isinstance(tgt, ast.Name) and tgt.id.endswith(suffix)):
            continue
        if isinstance(node.value, (ast.Tuple, ast.List)):
            vals = [
                e.value for e in node.value.elts
                if isinstance(e, ast.Constant) and isinstance(e.value, str)
            ]
            out[tgt.id] = vals
    return out


def _dict_keys(tree: ast.Module, name: str) -> List[str]:
    for node in tree.body:
        if (
            isinstance(node, ast.Assign)
            and len(node.targets) == 1
            and isinstance(node.targets[0], ast.Name)
            and node.targets[0].id == name
            and isinstance(node.value, ast.Dict)
        ):
            return [
                k.value for k in node.value.keys
                if isinstance(k, ast.Constant) and isinstance(k.value, str)
            ]
    return []


def check_backend_coverage(root: Path) -> List[LintViolation]:
    """RPR004 — cross-file: registry names vs test coverage declarations."""
    src_root = root / "src"
    grid_path = root / "tests" / "test_decode_api.py"
    golden_path = root / "tests" / "test_golden_ber.py"
    if not (src_root.exists() and grid_path.exists() and golden_path.exists()):
        return []  # partial checkout (e.g. linting a single file): skip
    names = registered_decoder_names(src_root)
    grid_tree = ast.parse(grid_path.read_text())
    golden_tree = ast.parse(golden_path.read_text())
    expected = set(
        _string_tuple_assigns(grid_tree, "EXPECTED_BACKENDS")
        .get("EXPECTED_BACKENDS", [])
    )
    golden_covered: Set[str] = set()
    for vals in _string_tuple_assigns(golden_tree, "_BACKENDS").values():
        golden_covered.update(vals)
    golden_covered.update(_dict_keys(golden_tree, "CODECS"))
    out: List[LintViolation] = []
    for name, (path, line) in sorted(names.items()):
        rel = _relpath(Path(path), root)
        if name not in expected:
            out.append(LintViolation(
                rule="RPR004", path=rel, line=line, col=0,
                message=f"backend {name!r} missing from "
                        "tests/test_decode_api.py EXPECTED_BACKENDS "
                        "(the equivalence grid)",
            ))
        if name not in golden_covered and name not in GOLDEN_BER_EXEMPT:
            out.append(LintViolation(
                rule="RPR004", path=rel, line=line, col=0,
                message=f"backend {name!r} has no golden BER coverage "
                        "(tests/test_golden_ber.py) and no "
                        "GOLDEN_BER_EXEMPT entry",
            ))
    return out


def _relpath(path: Path, root: Optional[Path]) -> str:
    try:
        return str(path.resolve().relative_to(root)) if root else str(path)
    except ValueError:
        return str(path)


def lint_paths(
    paths: Sequence[Path],
    repo_rules: bool = True,
) -> Tuple[List[LintViolation], int]:
    """Lint every .py under ``paths``.  Returns (violations, files checked).

    ``repo_rules``: also run the cross-file rules (RPR004) against the repo
    root inferred from the first path (skipped when no pyproject/tests are
    reachable, e.g. linting a loose file)."""
    paths = [Path(p) for p in paths]
    root = _repo_root(paths[0]) if paths else None
    violations: List[LintViolation] = []
    n_files = 0
    for path in _iter_py_files(paths):
        try:
            source = path.read_text()
            tree = ast.parse(source)
        except (SyntaxError, UnicodeDecodeError) as e:
            violations.append(LintViolation(
                rule="RPR000", path=_relpath(path, root), line=1, col=0,
                message=f"unparseable: {e}",
            ))
            continue
        n_files += 1
        rel = _relpath(path, root)
        in_src = "src/repro" in str(path.resolve()).replace("\\", "/")
        linter = _FileLinter(path, rel, source, in_src)
        linter.visit(tree)
        violations.extend(linter.violations)
    if repo_rules and root is not None:
        violations.extend(check_backend_coverage(root))
    violations.sort(key=lambda v: (v.path, v.line, v.rule))
    return violations, n_files


def count_pragmas(paths: Sequence[Path]) -> Dict[str, int]:
    """{rule: number of allow[] pragmas} across ``paths`` — the bench
    'analysis' section records this so a creeping pragma count is visible."""
    out: Dict[str, int] = {}
    for path in _iter_py_files([Path(p) for p in paths]):
        try:
            source = path.read_text()
        except (OSError, UnicodeDecodeError):
            continue
        for codes in find_pragmas(source).values():
            for code in codes:
                out[code] = out.get(code, 0) + 1
    return out
