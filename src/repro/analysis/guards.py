"""Runtime sanitizer guards for hot-path code.

:func:`sanitized` bundles the three runtime checks the repo's invariants
need, as one context manager:

  * ``jax.transfer_guard("disallow")`` — any *implicit* host<->device
    transfer inside the guarded region raises immediately (explicit
    ``jax.device_put`` / ``np.asarray`` stay legal: on CPU a device->host
    read is zero-copy and invisible to the transfer guard, which is why the
    guard alone was never enough and the host-sync counter below exists).
  * ``jax.debug_nans`` — a NaN produced by any guarded computation raises
    at the producing primitive instead of corrupting a path metric rows
    later.
  * a recompilation counter — every XLA ``backend_compile`` inside the
    region is counted via ``jax.monitoring``; a steady-state tick that
    recompiles is a shape-leak bug, and the spy-test idiom this replaces
    could not see it at all.
  * a host-sync counter — counts device->host materializations by hooking
    the two routes a ``jax.Array`` crosses to numpy: the module-level
    ``np.asarray``/``np.array`` entry points (the buffer-protocol path that
    bypasses ``__array__``) and the ``ArrayImpl._value`` cache property
    (the ``float()`` / ``.item()`` / implicit-conversion path).  Counting
    ``_value`` only when ``_npy_value`` is unset keeps cached re-reads free,
    matching the "one sync per tick" contract precisely.

The counters are process-global and the numpy patch is process-wide, so the
guard is deliberately **not** reentrant or thread-safe — it is a test/bench
harness, not a production wrapper.  Nesting raises.
"""
from __future__ import annotations

import contextlib
import dataclasses
import sys
import threading
from typing import Iterator, Optional

import jax
import numpy as np

__all__ = ["SanitizerReport", "SanitizerSnapshot", "sanitized", "compile_count"]

_COMPILE_EVENT_SUFFIX = "backend_compile_duration"
_compile_events = 0
_listener_installed = False
_lock = threading.Lock()
_active = False


def _on_event(name: str, secs: float, **_kw) -> None:
    global _compile_events
    if name.endswith(_COMPILE_EVENT_SUFFIX):
        _compile_events += 1


def _install_compile_listener() -> None:
    """Install the global compile-event listener exactly once.

    jax.monitoring has no public unregister API, so the listener stays for
    the life of the process; it is a single integer increment per compile,
    which is noise next to the compile itself."""
    global _listener_installed
    with _lock:
        if not _listener_installed:
            jax.monitoring.register_event_duration_secs_listener(_on_event)
            _listener_installed = True


def compile_count() -> int:
    """Process-wide count of backend compiles seen by the listener."""
    return _compile_events


class SanitizerReport:
    """Filled in while a :func:`sanitized` region runs.

    ``host_syncs`` and ``recompiles`` are live counters — readable mid-region
    (e.g. snapshot between ticks to assert a per-tick bound) and final once
    the region exits (``recompiles`` freezes at its exit value)."""

    def __init__(
        self,
        transfer_guard: Optional[str] = "disallow",
        debug_nans: bool = True,
        compile_base: int = 0,
    ):
        self.host_syncs = 0
        self.transfer_guard = transfer_guard
        self.debug_nans = debug_nans
        self._compile_base = compile_base
        self._frozen_recompiles: Optional[int] = None

    @property
    def recompiles(self) -> int:
        if self._frozen_recompiles is not None:
            return self._frozen_recompiles
        return _compile_events - self._compile_base

    def _freeze(self) -> None:
        self._frozen_recompiles = _compile_events - self._compile_base

    def snapshot(self) -> "SanitizerSnapshot":
        return SanitizerSnapshot(
            host_syncs=self.host_syncs, recompiles=self.recompiles
        )

    @contextlib.contextmanager
    def allow_transfers(self) -> Iterator[None]:
        """Explicitly sanctioned control-plane window: suspends the transfer
        guard (a nested ``jax.transfer_guard("allow")`` overrides the outer
        disallow) while the counters keep running.  Use around setup that is
        *allowed* to move data — stream admission, warm-up compiles — so the
        steady-state region stays fully guarded."""
        with jax.transfer_guard("allow"):
            yield


@dataclasses.dataclass(frozen=True)
class SanitizerSnapshot:
    """Point-in-time copy of the live counters."""

    host_syncs: int
    recompiles: int


def _caller_is_jax() -> bool:
    """True when the frame initiating a host materialization is jax's own
    machinery (e.g. debug_nans' ``_check_special`` reads every computation
    output back to check it) — those are sanitizer overhead, not user
    syncs, and counting them would make ``debug_nans`` and an exact
    host-sync bound mutually exclusive."""
    frame = sys._getframe(2)
    name = frame.f_globals.get("__name__", "")
    return name == "jax" or name.startswith("jax.")


class _HostSyncHooks:
    """Patch np.asarray/np.array and ArrayImpl._value to count syncs."""

    def __init__(self, report: SanitizerReport):
        self.report = report
        self._orig_asarray = np.asarray
        self._orig_array = np.array
        from jax._src.array import ArrayImpl

        self._array_impl = ArrayImpl
        self._orig_value = ArrayImpl._value

    def _wrap_np(self, orig):
        report = self.report

        def counting(obj, *args, **kwargs):
            if isinstance(obj, jax.Array) and not _caller_is_jax():
                report.host_syncs += 1
            return orig(obj, *args, **kwargs)

        # tests that interpose their own spy above this wrapper use _orig to
        # route jax-internal calls around the counter (their frame would
        # otherwise defeat the caller check)
        counting._orig = orig
        return counting

    def __enter__(self):
        np.asarray = self._wrap_np(self._orig_asarray)
        np.array = self._wrap_np(self._orig_array)
        report = self.report
        orig_value = self._orig_value

        # no jax-caller filter here: float()/.item() always route through
        # jax's own __float__/__index__ shims, so the immediate caller is
        # jax by construction — and jax's sanitizer machinery (the reason
        # the filter exists on the asarray path) reads via np.asarray, not
        # ._value
        def counting_value(impl_self):
            if getattr(impl_self, "_npy_value", None) is None:
                report.host_syncs += 1
            return orig_value.fget(impl_self)

        setattr(self._array_impl, "_value", property(counting_value))
        return self

    def __exit__(self, *exc):
        np.asarray = self._orig_asarray
        np.array = self._orig_array
        setattr(self._array_impl, "_value", self._orig_value)
        return False


@contextlib.contextmanager
def sanitized(
    transfer_guard: Optional[str] = "disallow",
    debug_nans: bool = True,
    count_host_syncs: bool = True,
) -> Iterator[SanitizerReport]:
    """Run the enclosed block under the full sanitizer bundle.

    Yields a live :class:`SanitizerReport`.  Typical use::

        with sanitized() as rep:
            tick()                       # warm: may compile
            base = rep.snapshot()
            tick()                       # steady state
        assert rep.recompiles == base.recompiles          # no shape leak
        assert rep.host_syncs - base.host_syncs == 1      # the one sync

    ``transfer_guard=None`` / ``debug_nans=False`` / ``count_host_syncs=
    False`` disable individual layers (the bench --sanitize mode keeps all
    three on)."""
    global _active
    with _lock:
        if _active:
            raise RuntimeError("sanitized() does not nest")
        _active = True
    _install_compile_listener()
    report = SanitizerReport(
        transfer_guard=transfer_guard,
        debug_nans=debug_nans,
        compile_base=_compile_events,
    )
    try:
        with contextlib.ExitStack() as stack:
            if transfer_guard is not None:
                stack.enter_context(jax.transfer_guard(transfer_guard))
            if debug_nans:
                stack.enter_context(jax.debug_nans(True))
            if count_host_syncs:
                stack.enter_context(_HostSyncHooks(report))
            yield report
    finally:
        report._freeze()
        with _lock:
            _active = False
