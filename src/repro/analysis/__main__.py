"""``python -m repro.analysis [paths...]`` — run the repo-rule linter
(and, with ``--jaxpr``, the jaxpr contract lint over every registered hot
path).  Exit status: 0 clean, 1 violations, 2 usage error.

This is the CI `static-analysis` entry point; keep its output stable:
one line per violation, a final ``summary`` line with counts.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.analysis.repo_lint import RULES, count_pragmas, lint_paths
from repro.obs.log import get_logger


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-rule linter (RPR001-RPR005) + jaxpr contract lint",
    )
    parser.add_argument(
        "paths", nargs="*", default=["src"],
        help="files or directories to lint (default: src)",
    )
    parser.add_argument(
        "--jaxpr", action="store_true",
        help="also trace every registered hot path against its Contract "
             "(imports jax and the decode registry; slower)",
    )
    parser.add_argument(
        "--no-repo-rules", action="store_true",
        help="skip the cross-file rules (RPR004 registry/test coverage)",
    )
    parser.add_argument("--quiet", action="store_true")
    args = parser.parse_args(argv)
    log = get_logger("analysis.cli", quiet=args.quiet)

    paths = [Path(p) for p in args.paths]
    missing = [p for p in paths if not p.exists()]
    if missing:
        log.error("no such path", paths=",".join(map(str, missing)))
        return 2

    violations, n_files = lint_paths(paths, repo_rules=not args.no_repo_rules)
    for v in violations:
        log.warning(str(v))

    n_contract = 0
    n_paths_traced = 0
    if args.jaxpr:
        from repro.analysis.hotpaths import check_hot_paths

        report = check_hot_paths()
        n_paths_traced = len(report)
        for name, entry in sorted(report.items()):
            for v in entry["violations"]:
                n_contract += 1
                log.warning(str(v))
            log.info(
                "traced", path=name, backend=entry["backend"],
                equations=entry["equations"],
                violations=len(entry["violations"]),
            )

    pragmas = count_pragmas(paths)
    log.info(
        "summary",
        files=n_files,
        rules=len(RULES),
        lint_violations=len(violations),
        hot_paths_traced=n_paths_traced,
        contract_violations=n_contract,
        pragmas=sum(pragmas.values()),
    )
    return 1 if (violations or n_contract) else 0


if __name__ == "__main__":
    sys.exit(main())
