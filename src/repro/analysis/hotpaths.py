"""The registered hot-path catalog: what the jaxpr contract lint traces.

Every decoder in the registry maps to exactly ONE catalog entry that knows
how to build a traceable callable for its hot loop plus the :class:`Contract`
that loop must satisfy:

  * the block backends (sequential / parallel / fused / fused_packed /
    tiled / bcjr) trace their registry entry directly on a small abstract
    workload;
  * the scheduler-driven backends (streaming, sharded_stream) are Python
    orchestration around a jitted tick — the tick body IS the hot path, so
    the catalog traces ``stream_step`` / ``make_sharded_stream_step``
    (the shard_map variant, device counters on: the richest tick we ship);
  * seqparallel traces under a unit ``data`` mesh with its seam-gather
    collectives explicitly allowlisted — everything else is comms-free;
  * turbo's Python-level iteration loop carries host-side early-exit
    bookkeeping, so its catalog entry traces the jitted single-iteration
    SISO pass (two BCJR kernel launches + extrinsic exchange), which is
    where all its device time goes.

``check_hot_paths()`` is the CI entry: it asserts the catalog covers every
registered decoder (a new backend without a contract fails the build) and
returns a per-path report of equation counts and violations.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.jaxpr_lint import Contract, ContractViolation, trace_contract

#: outputs of a block decode: (bits, path_metric)
_BLOCK_OUTPUTS = 2
#: outputs of the plain tick: (pm, ring, committed_bits, offset_delta)
_TICK_OUTPUTS = 4


@dataclasses.dataclass(frozen=True)
class HotPath:
    """One traceable hot path: its backend, its contract, and a builder
    returning ``(fn, args)`` ready for ``jax.make_jaxpr``."""

    name: str
    backend: str               # the registry entry this path covers
    contract: Contract
    build: Callable[[], Tuple[Callable, Sequence]]
    summary: str = ""


def _unit_mesh(axis: str = "data"):
    from jax.sharding import Mesh

    return Mesh(np.asarray(jax.devices()[:1]), (axis,))


def _conv_spec():
    from repro.configs.paper_viterbi import DECODE_SPEC

    return DECODE_SPEC


def _rsc_spec():
    from repro.decode import CodecSpec
    from repro.siso import RSC_K4_LTE

    return CodecSpec(code=RSC_K4_LTE, metric="soft", terminated=False)


def _block_builder(backend: str, B: int = 2, T: int = 64):
    """Registry backend on an abstract (B, T, M) bm table, interpret mode
    resolved ONCE up front (the pinning rule the repo-rule linter enforces
    at call sites)."""

    def build():
        from repro.decode import DecodeContext, get_decoder
        from repro.kernels.common import resolve_interpret

        spec = _conv_spec()
        ctx = DecodeContext(interpret=resolve_interpret(None), chunk=32)
        dec = get_decoder(backend)
        bm = jax.ShapeDtypeStruct((B, T, 2 ** spec.code.n_out), jnp.float32)

        def fn(tables):
            res = dec(spec, tables, ctx=ctx)
            return res.bits, res.path_metric

        return fn, (bm,)

    return build


def _seqparallel_builder():
    def build():
        from repro.decode import DecodeContext, get_decoder
        from repro.kernels.common import resolve_interpret

        spec = _conv_spec()
        mesh = _unit_mesh()
        ctx = DecodeContext(
            interpret=resolve_interpret(None), mesh=mesh, mesh_axis="data"
        )
        dec = get_decoder("seqparallel")
        bm = jax.ShapeDtypeStruct((2, 64, 2 ** spec.code.n_out), jnp.float32)

        def fn(tables):
            res = dec(spec, tables, ctx=ctx)
            return res.bits, res.path_metric

        return fn, (bm,)

    return build


def _stream_tick_builder(chunk: int = 32):
    """The single-device tick body behind sessions and the scheduler
    (streaming backend): one stream_step over carried state."""

    def build():
        from repro.kernels.common import resolve_interpret
        from repro.stream import window as w

        spec = _conv_spec()
        code = spec.code
        interpret = resolve_interpret(None)
        B, depth = 4, w.default_depth(code)
        R = depth + chunk
        pm = jax.ShapeDtypeStruct((B, code.n_states), jnp.float32)
        ring = jax.ShapeDtypeStruct((R, B, code.n_states), jnp.int32)
        chunk_bm = jax.ShapeDtypeStruct((B, chunk, 2 ** code.n_out), jnp.float32)
        active = jax.ShapeDtypeStruct((B,), jnp.bool_)

        def fn(pm, ring, chunk_bm, active):
            state, bits, delta = w.stream_step(
                code, w.StreamState(pm=pm, ring=ring), chunk_bm,
                active=active, backend="fused", interpret=interpret,
            )
            return state.pm, state.ring, bits, delta

        return fn, (pm, ring, chunk_bm, active)

    return build


def _sharded_tick_builder(chunk: int = 32):
    """The shard_map tick (sharded_stream backend) with device counters on —
    the richest per-tick computation we ship, and the one whose comms-free
    guarantee the multi-device scaling depends on."""

    def build():
        from repro.kernels.common import PACK_BITS, resolve_interpret
        from repro.stream import window as w

        spec = _conv_spec()
        code = spec.code
        mesh = _unit_mesh()
        tick = w.make_sharded_stream_step(
            code, mesh, "data", chunk=chunk, backend=w.PACKED_BACKEND,
            interpret=resolve_interpret(None), device_metrics=True,
        )
        B = 4
        depth = w.packed_depth(w.default_depth(code))
        R = depth + chunk
        arena = jax.ShapeDtypeStruct((1, 4 * chunk, 2 ** code.n_out), jnp.float32)
        idx = jax.ShapeDtypeStruct((B, chunk), jnp.int32)
        active = jax.ShapeDtypeStruct((B,), jnp.bool_)
        pm = jax.ShapeDtypeStruct((B, code.n_states), jnp.float32)
        ring = jax.ShapeDtypeStruct((R // PACK_BITS, B, code.n_states), jnp.uint32)
        ctr_i = jax.ShapeDtypeStruct((B,), jnp.int32)
        ctr_f = jax.ShapeDtypeStruct((B,), jnp.float32)
        counters = w.DeviceCounters(
            ticks=ctr_i, starved_ticks=ctr_i, merge_depth_last=ctr_i,
            merge_depth_sum=ctr_f, merge_depth_max=ctr_i, renorm_sum=ctr_f,
        )

        def fn(arena, idx, active, pm, ring, *ctr):
            state, bits, delta, out_ctr = tick(
                arena, idx, active, w.StreamState(pm=pm, ring=ring),
                w.DeviceCounters(*ctr),
            )
            return (state.pm, state.ring, bits, delta) + tuple(out_ctr)

        return fn, (arena, idx, active, pm, ring) + tuple(counters)

    return build


def _bcjr_builder(B: int = 2, N: int = 64):
    def build():
        from repro.decode import DecodeContext, get_decoder
        from repro.kernels.common import resolve_interpret

        spec = _rsc_spec()
        ctx = DecodeContext(interpret=resolve_interpret(None))
        dec = get_decoder("bcjr")
        llr = jax.ShapeDtypeStruct((B, N, 1 + spec.code.n_parity), jnp.float32)

        def fn(llr_coded):
            res = dec(spec, llr_coded, ctx=ctx)
            return res.bits, res.path_metric

        return fn, (llr,)

    return build


def _turbo_iteration_builder(B: int = 2):
    def build():
        from repro.kernels.common import resolve_interpret
        from repro.siso import QPPInterleaver, RSC_K4_LTE, TurboSpec
        from repro.siso.turbo import _iteration_fn

        spec = TurboSpec(code=RSC_K4_LTE, interleaver=QPPInterleaver(64, 7, 16))
        step = _iteration_fn(spec, resolve_interpret(None))
        N = spec.block_len
        llrs = jax.ShapeDtypeStruct((B, N, spec.n_streams), jnp.float32)
        le2 = jax.ShapeDtypeStruct((B, N), jnp.float32)
        prev = jax.ShapeDtypeStruct((B, N), jnp.int32)
        done = jax.ShapeDtypeStruct((B,), jnp.bool_)
        return step, (llrs, le2, prev, done)

    return build


def _contract(name: str, **kw) -> Contract:
    return Contract(name=name, **kw)


def hot_path_catalog() -> Tuple[HotPath, ...]:
    """One entry per registered decoder.  Adding a backend without extending
    this catalog fails ``check_hot_paths`` (and the CI static-analysis job)."""
    comms_free = dict(allowed_collectives=frozenset())
    return (
        HotPath(
            name="sequential", backend="sequential",
            contract=_contract("sequential", max_outputs=_BLOCK_OUTPUTS,
                               **comms_free),
            build=_block_builder("sequential"),
            summary="lax.scan oracle block decode",
        ),
        HotPath(
            name="parallel", backend="parallel",
            contract=_contract("parallel", max_outputs=_BLOCK_OUTPUTS,
                               **comms_free),
            build=_block_builder("parallel"),
            summary="(min,+) associative-scan block decode",
        ),
        HotPath(
            name="fused", backend="fused",
            contract=_contract("fused", max_outputs=_BLOCK_OUTPUTS,
                               **comms_free),
            build=_block_builder("fused"),
            summary="Pallas Texpand scan block decode",
        ),
        HotPath(
            name="fused_packed", backend="fused_packed",
            contract=_contract("fused_packed", max_outputs=_BLOCK_OUTPUTS,
                               **comms_free),
            build=_block_builder("fused_packed"),
            summary="packed-survivor Pallas pipeline",
        ),
        HotPath(
            name="tiled", backend="tiled",
            contract=_contract("tiled", max_outputs=_BLOCK_OUTPUTS,
                               **comms_free),
            build=_block_builder("tiled", T=128),
            summary="time-parallel tiled decode, exact min-plus seams",
        ),
        HotPath(
            name="seqparallel", backend="seqparallel",
            # the ONE path allowed to communicate: it gathers per-chunk
            # (S, S) transfer maps across the time shards — tiny, T-independent
            contract=_contract(
                "seqparallel", max_outputs=_BLOCK_OUTPUTS,
                allowed_collectives=frozenset({"all_gather", "psum"}),
            ),
            build=_seqparallel_builder(),
            summary="shard_map sequence-parallel decode (seam gather)",
        ),
        HotPath(
            name="stream_tick", backend="streaming",
            contract=_contract("stream_tick", max_outputs=_TICK_OUTPUTS,
                               **comms_free),
            build=_stream_tick_builder(),
            summary="single-device session/scheduler tick body",
        ),
        HotPath(
            name="sharded_stream_tick", backend="sharded_stream",
            # comms-free by construction: slots are independent streams, so
            # the shard_map body must contain ZERO collectives
            contract=_contract(
                "sharded_stream_tick",
                max_outputs=_TICK_OUTPUTS + 6,  # + DeviceCounters leaves
                **comms_free,
            ),
            build=_sharded_tick_builder(),
            summary="sharded shard_map tick, device counters on",
        ),
        HotPath(
            name="bcjr", backend="bcjr",
            contract=_contract("bcjr", max_outputs=_BLOCK_OUTPUTS,
                               **comms_free),
            build=_bcjr_builder(),
            summary="max-log-MAP BCJR kernel pair (alpha scan + beta/LLR)",
        ),
        HotPath(
            name="turbo_iteration", backend="turbo",
            # (le2, bits, llr, done, agree) from the jitted iteration
            contract=_contract("turbo_iteration", max_outputs=5, **comms_free),
            build=_turbo_iteration_builder(),
            summary="jitted turbo iteration (2 BCJR SISO passes)",
        ),
    )


def check_hot_paths(
    catalog: Tuple[HotPath, ...] = None,
) -> Dict[str, Dict[str, object]]:
    """Trace every catalog entry and check its contract.

    Returns {path name: {backend, equations, violations: [...], summary}}.
    Raises AssertionError if the catalog does not cover the full decoder
    registry — tracing "every backend" must mean every backend."""
    from repro.decode import list_decoders

    paths = hot_path_catalog() if catalog is None else catalog
    covered = {p.backend for p in paths}
    registered = set(list_decoders())
    assert covered == registered, (
        f"hot-path catalog out of sync with the registry: "
        f"missing {sorted(registered - covered)}, "
        f"stale {sorted(covered - registered)}"
    )
    report: Dict[str, Dict[str, object]] = {}
    for p in paths:
        fn, args = p.build()
        closed, violations = trace_contract(fn, args, p.contract)
        report[p.name] = {
            "backend": p.backend,
            "equations": _count_eqns(closed.jaxpr),
            "violations": violations,
            "summary": p.summary,
        }
    return report


def _count_eqns(jaxpr) -> int:
    from repro.analysis.jaxpr_lint import _sub_jaxprs

    n = len(jaxpr.eqns)
    for eqn in jaxpr.eqns:
        for param in eqn.params.values():
            for sub in _sub_jaxprs(param):
                n += _count_eqns(sub)
    return n


def flatten_violations(
    report: Dict[str, Dict[str, object]],
) -> List[ContractViolation]:
    out: List[ContractViolation] = []
    for row in report.values():
        out.extend(row["violations"])
    return out
