"""Static analysis & runtime contracts for the decode hot paths.

Three layers:

  * :mod:`repro.analysis.jaxpr_lint` — declarative :class:`Contract`s
    checked equation-by-equation against traced jaxprs (host callbacks,
    collectives, dtype policy, output bounds).
  * :mod:`repro.analysis.repo_lint` — AST rules RPR001–RPR005 for the
    conventions the codebase relies on (no print, resolve_interpret
    routing, hot-path host-sync hygiene, registry/test coverage,
    explicit backend family), with line-scoped ``# repr-lint: allow[...]``
    pragmas.
  * :mod:`repro.analysis.guards` — the :func:`sanitized` runtime bundle
    (transfer guard + debug-NaNs + recompile and host-sync counters).

CLI: ``python -m repro.analysis src`` (add ``--jaxpr`` to also trace every
registered hot path).  Exit status 0 means clean.
"""
from repro.analysis.guards import (
    SanitizerReport,
    SanitizerSnapshot,
    compile_count,
    sanitized,
)
from repro.analysis.hotpaths import (
    HotPath,
    check_hot_paths,
    flatten_violations,
    hot_path_catalog,
)
from repro.analysis.jaxpr_lint import (
    COLLECTIVE_PRIMS,
    HOST_CALLBACK_PRIMS,
    Contract,
    ContractViolation,
    check_jaxpr,
    trace_contract,
)
from repro.analysis.repo_lint import (
    GOLDEN_BER_EXEMPT,
    RULES,
    LintViolation,
    count_pragmas,
    find_pragmas,
    lint_paths,
)

__all__ = [
    "COLLECTIVE_PRIMS",
    "Contract",
    "ContractViolation",
    "GOLDEN_BER_EXEMPT",
    "HOST_CALLBACK_PRIMS",
    "HotPath",
    "LintViolation",
    "RULES",
    "SanitizerReport",
    "SanitizerSnapshot",
    "check_hot_paths",
    "check_jaxpr",
    "compile_count",
    "count_pragmas",
    "find_pragmas",
    "flatten_violations",
    "hot_path_catalog",
    "lint_paths",
    "sanitized",
    "trace_contract",
]
