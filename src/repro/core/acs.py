"""Reference (pure-jnp) Add-Compare-Select — the paper's `Texpand` primitive.

This is the oracle the Pallas kernels are validated against (kernels/ref.py
re-exports it).  The butterfly formulation avoids gathers entirely — see
trellis.py docstring.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.trellis import ConvCode


def acs_step(code: ConvCode, pm: jnp.ndarray, bm_table: jnp.ndarray):
    """One trellis-expansion (ACS) step for all states, batched.

    Args:
      pm: (..., S) float32 path metrics.
      bm_table: (..., n_symbols) float32 per-step branch-metric table
        (bm_table[c] = metric of emitting symbol c at this step).

    Returns:
      new_pm: (..., S) updated path metrics.
      bp: (..., S) int32 backpointer bit j ∈ {0,1}; predecessor of successor
        state ``s' = u*S/2 + v`` is ``2v + j``.  Ties select j=0 (the paper's
        lowest-state rule, since 2v < 2v+1).
    """
    S = code.n_states
    oh = jnp.asarray(code.butterfly_onehot)  # (2, S/2, 2, M)
    # branch metric per (input-bit u, low-state v, pred-parity j)
    bm = jnp.einsum("uvjm,...m->...uvj", oh, bm_table)  # (..., 2, S/2, 2)
    pm2 = pm.reshape(pm.shape[:-1] + (S // 2, 2))  # pm2[..., v, j] = pm[..., 2v+j]
    cand = pm2[..., None, :, :] + bm  # (..., 2, S/2, 2)
    take1 = cand[..., 1] < cand[..., 0]  # strict: ties -> j=0 (lowest pred state)
    new_pm = jnp.where(take1, cand[..., 1], cand[..., 0])
    new_pm = new_pm.reshape(pm.shape[:-1] + (S,))
    bp = take1.astype(jnp.int32).reshape(pm.shape[:-1] + (S,))
    return new_pm, bp


def acs_step_unfused(code: ConvCode, pm: jnp.ndarray, bm_table: jnp.ndarray):
    """Deliberately *unfused* ACS, mirroring the paper's plain-assembly
    trellis function: explicit per-transition adds, then compares, then
    selects, using gathers on the predecessor/branch tables.

    Semantically identical to :func:`acs_step`; used as the "without custom
    instruction" baseline in the benchmarks (it lowers to many more HLO ops).
    """
    S = code.n_states
    nxt = code.next_state  # (S, 2) numpy: loop bounds stay static under trace
    bcode = code.branch_code  # (S, 2)
    big = jnp.asarray(3.4e38, dtype=pm.dtype)
    new_pm = jnp.full(pm.shape, big)
    best_pred_parity = jnp.zeros(pm.shape, dtype=jnp.int32)
    # iterate transitions exactly like the assembly loop: for each predecessor
    # state p and input u, ADD branch metric, COMPARE against incumbent,
    # SELECT the survivor.
    for p in range(S):
        for u in (0, 1):
            sp = int(nxt[p, u])
            cand = pm[..., p] + bm_table[..., int(bcode[p, u])]  # ADD
            incumbent = new_pm[..., sp]
            better = cand < incumbent  # COMPARE (strict: earlier p wins ties)
            new_pm = new_pm.at[..., sp].set(jnp.where(better, cand, incumbent))  # SELECT
            best_pred_parity = best_pred_parity.at[..., sp].set(
                jnp.where(better, p & 1, best_pred_parity[..., sp])
            )
    return new_pm, best_pred_parity
