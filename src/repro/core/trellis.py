"""Convolutional-code / trellis specification.

State convention (documented in DESIGN.md §2):
  the encoder register at time t holds ``[u_t, u_{t-1}, ..., u_{t-K+1}]``
  (K bits, newest first).  The *state* is the top K-1 bits **after** the
  shift, i.e. ``s_t = (u_t << (K-2)) | (s_{t-1} >> 1)``.

Butterfly structure (no gathers — see DESIGN.md):
  write the successor state as ``s' = u * S/2 + v`` (``u`` = MSB = the input
  bit that produced the transition, ``v`` = low K-2 bits).  Its two
  predecessors are ``p0 = 2v`` and ``p1 = 2v + 1``.  The ACS step is then a
  reshape + elementwise min — a de Bruijn butterfly, like an FFT stage.

Tie-break rule (paper §IV-B): when the two arriving path weights are equal,
the path arriving from the **lowest-numbered state** survives.  Since
``p0 = 2v < p1 = 2v+1``, the ACS select must prefer ``j=0`` on ties
(strict ``<`` when testing the ``j=1`` candidate).
"""
from __future__ import annotations

import dataclasses
from functools import cached_property
from typing import Tuple

import numpy as np

# A value that acts as +inf in (min,+) arithmetic but stays finite so that
# minplus matrix products never produce NaN (inf - inf).
NEG_UNREACHABLE = 1e30


def _parity(x: int) -> int:
    return bin(x).count("1") & 1


@dataclasses.dataclass(frozen=True)
class ConvCode:
    """Rate 1/n feed-forward convolutional code.

    Attributes:
      constraint: constraint length K (register holds K bits).
      polys: generator polynomials, one per output bit, as integers of K bits.
        Bit ``K-1`` (MSB) taps the *current* input bit ``u_t``; bit 0 taps the
        oldest bit ``u_{t-K+1}``.
    """

    constraint: int = 3
    polys: Tuple[int, ...] = (0b111, 0b101)  # the standard (7,5) K=3 code

    def __post_init__(self):
        if self.constraint < 2:
            raise ValueError("constraint length must be >= 2")
        for g in self.polys:
            if not 0 <= g < (1 << self.constraint):
                raise ValueError(f"poly {g:#o} does not fit in K={self.constraint} bits")

    @property
    def n_out(self) -> int:
        """Output bits per input bit (rate is 1/n_out)."""
        return len(self.polys)

    @property
    def n_states(self) -> int:
        return 1 << (self.constraint - 1)

    @property
    def n_symbols(self) -> int:
        """Number of distinct output symbols (packed output bit patterns)."""
        return 1 << self.n_out

    # ------------------------------------------------------------------ #
    # Static tables (numpy; baked into jitted functions as constants).    #
    # ------------------------------------------------------------------ #

    @cached_property
    def branch_code(self) -> np.ndarray:
        """(S, 2) int32: packed output symbol for transition (state=p, input=u)."""
        K, S = self.constraint, self.n_states
        out = np.zeros((S, 2), dtype=np.int32)
        for p in range(S):
            for u in (0, 1):
                reg = (u << (K - 1)) | p
                c = 0
                for g in self.polys:
                    c = (c << 1) | _parity(g & reg)
                out[p, u] = c
        return out

    @cached_property
    def next_state(self) -> np.ndarray:
        """(S, 2) int32: successor state for (state=p, input=u)."""
        K, S = self.constraint, self.n_states
        nxt = np.zeros((S, 2), dtype=np.int32)
        for p in range(S):
            for u in (0, 1):
                nxt[p, u] = (u << (K - 2)) | (p >> 1)
        return nxt

    @cached_property
    def butterfly_code(self) -> np.ndarray:
        """(2, S//2, 2) int32: packed output symbol for the butterfly ACS.

        ``butterfly_code[u, v, j]`` is the output symbol of the transition
        from predecessor ``p = 2v + j`` into successor ``s' = u*S/2 + v``.
        """
        S = self.n_states
        bc = self.branch_code  # (S, 2)
        out = np.zeros((2, S // 2, 2), dtype=np.int32)
        for u in (0, 1):
            for v in range(S // 2):
                for j in (0, 1):
                    out[u, v, j] = bc[2 * v + j, u]
        return out

    @cached_property
    def butterfly_onehot(self) -> np.ndarray:
        """(2, S//2, 2, n_symbols) float32 one-hot of ``butterfly_code``.

        Lets the branch-metric lookup be an MXU matmul:
        ``bm[u, v, j, b] = onehot[u, v, j, :] @ bm_table[b, :]``.
        """
        oh = np.zeros((2, self.n_states // 2, 2, self.n_symbols), dtype=np.float32)
        code = self.butterfly_code
        for u in (0, 1):
            for v in range(self.n_states // 2):
                for j in (0, 1):
                    oh[u, v, j, code[u, v, j]] = 1.0
        return oh

    @cached_property
    def select_matrices(self) -> Tuple[np.ndarray, np.ndarray]:
        """(P0, P1), each (S, S) float32 one-hot permutation matrices.

        ``P_j[s', p] = 1`` iff ``p = 2v + j`` is the j-th predecessor of
        ``s' = u*S/2 + v``.  They turn the predecessor gather of the ACS step
        into an MXU matmul: ``pm_prev_j = P_j @ pm`` for column-major
        (state, batch) layout.  This is the TPU-native form used by the
        Pallas kernels (no gathers on the systolic path).
        """
        S = self.n_states
        P0 = np.zeros((S, S), dtype=np.float32)
        P1 = np.zeros((S, S), dtype=np.float32)
        half = S // 2
        for sp in range(S):
            v = sp % half
            P0[sp, 2 * v] = 1.0
            P1[sp, 2 * v + 1] = 1.0
        return P0, P1

    @cached_property
    def branch_onehot_pair(self) -> Tuple[np.ndarray, np.ndarray]:
        """(OH0, OH1), each (S, n_symbols) float32.

        ``OH_j[s', c] = 1`` iff symbol c is emitted on the transition from
        predecessor ``2v+j`` into successor s'.  Branch-metric lookup becomes
        ``bm_j = OH_j @ bm_table`` for (symbol, batch)-layout tables.
        """
        S, M = self.n_states, self.n_symbols
        half = S // 2
        bc = self.branch_code
        OH0 = np.zeros((S, M), dtype=np.float32)
        OH1 = np.zeros((S, M), dtype=np.float32)
        for sp in range(S):
            u, v = sp // half, sp % half
            OH0[sp, bc[2 * v, u]] = 1.0
            OH1[sp, bc[2 * v + 1, u]] = 1.0
        return OH0, OH1

    @cached_property
    def hamming_table(self) -> np.ndarray:
        """(n_symbols, n_symbols) float32: popcount(a XOR b)."""
        M = self.n_symbols
        t = np.zeros((M, M), dtype=np.float32)
        for a in range(M):
            for b in range(M):
                t[a, b] = bin(a ^ b).count("1")
        return t

    @cached_property
    def symbol_bits(self) -> np.ndarray:
        """(n_symbols, n_out) float32: bit expansion of each packed symbol."""
        M, n = self.n_symbols, self.n_out
        t = np.zeros((M, n), dtype=np.float32)
        for c in range(M):
            for j in range(n):
                t[c, j] = (c >> (n - 1 - j)) & 1
        return t


# Named codes used throughout tests/benchmarks/examples.
CODE_K3_STD = ConvCode(3, (0b111, 0b101))        # (7,5): the textbook K=3 code
CODE_K3_PAPER = ConvCode(3, (0b110, 0b010))      # the encoder of the paper's Fig. 1(b)
CODE_K5_GSM = ConvCode(5, (0b10011, 0b11101))    # GSM full-rate (23, 35)_oct, K=5
CODE_K7_NASA = ConvCode(7, (0o171, 0o133))       # NASA/Voyager K=7 (171,133)


def paper_expansion_calls(n_coded_bits: int, code: ConvCode = CODE_K3_STD) -> int:
    """Number of trellis-expansion calls as counted by the paper (§V).

    For the 4-state K=3 trellis and 12 coded bits the paper counts 19 calls:
    the active-state frontier grows 1, 2, 4, 4, ... so the total over
    T = n_coded_bits / n_out steps is ``sum_t min(2^t, S)``.
    """
    T = n_coded_bits // code.n_out
    S = code.n_states
    return int(sum(min(2 ** t, S) for t in range(T)))
