"""Convolutional encoder — a discrete-time LTI system over GF(2), in JAX.

Fully vectorized (no scan): output bit j at time t is the GF(2) inner
product of generator polynomial j with the register window
``[u_t, ..., u_{t-K+1}]``.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.trellis import ConvCode


def encode(code: ConvCode, bits: jnp.ndarray, terminate: bool = True) -> jnp.ndarray:
    """Encode information bits.

    Args:
      code: the convolutional code.
      bits: (..., T) array of {0,1} information bits.
      terminate: if True, append K-1 zero flush bits (paper's convention: the
        trellis starts AND ends in state 0).

    Returns:
      (..., T_out, n_out) array of {0,1} coded bits, where
      T_out = T + (K-1 if terminate else 0).
    """
    bits = jnp.asarray(bits)
    K = code.constraint
    if terminate:
        flush = jnp.zeros(bits.shape[:-1] + (K - 1,), dtype=bits.dtype)
        bits = jnp.concatenate([bits, flush], axis=-1)
    T = bits.shape[-1]
    # window[..., t, i] = u_{t-i} (zero before start)
    pad = jnp.concatenate(
        [jnp.zeros(bits.shape[:-1] + (K - 1,), dtype=bits.dtype), bits], axis=-1
    )
    idx = jnp.arange(T)[:, None] + (K - 1) - jnp.arange(K)[None, :]  # (T, K)
    window = pad[..., idx]  # (..., T, K) — window[..., t, i] = u_{t-i}
    # generator taps: poly bit (K-1-i) multiplies u_{t-i}
    taps = np.zeros((len(code.polys), K), dtype=np.int32)
    for j, g in enumerate(code.polys):
        for i in range(K):
            taps[j, i] = (g >> (K - 1 - i)) & 1
    taps = jnp.asarray(taps)
    # GF(2) inner product = parity of AND
    out = jnp.einsum("...tk,jk->...tj", window.astype(jnp.int32), taps) % 2
    return out.astype(jnp.int32)


def pack_symbols(code: ConvCode, coded_bits: jnp.ndarray) -> jnp.ndarray:
    """Pack (..., T, n_out) coded bits into (..., T) int32 symbols."""
    n = code.n_out
    weights = jnp.asarray([1 << (n - 1 - j) for j in range(n)], dtype=jnp.int32)
    return jnp.einsum("...tj,j->...t", coded_bits.astype(jnp.int32), weights)


def unpack_symbols(code: ConvCode, symbols: jnp.ndarray) -> jnp.ndarray:
    """Unpack (..., T) int32 symbols into (..., T, n_out) bits."""
    n = code.n_out
    shifts = jnp.asarray([n - 1 - j for j in range(n)], dtype=jnp.int32)
    return (symbols[..., None] >> shifts) & 1
