"""Rate-compatible punctured convolutional codes.

The paper's Texpand targets rate-1/2 codes; real systems (GSM/LTE/DVB — the
paper's digital-TV motivation) derive higher rates by *puncturing*: deleting
coded bits by a periodic pattern at the transmitter and treating them as
erasures at the receiver.  Erasure handling costs nothing in our decoder:
punctured positions contribute 0 to every branch metric, so the SAME fused
ACS kernels decode any punctured rate.

Patterns are (n_out, period) 0/1 arrays; e.g. rate-2/3 from rate-1/2:
P = [[1, 1], [1, 0]] — every second bit of the second stream is dropped.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.trellis import ConvCode

# standard patterns (period aligned per input bit)
PUNCTURE_2_3 = np.array([[1, 1], [1, 0]])
PUNCTURE_3_4 = np.array([[1, 1, 0], [1, 0, 1]])
PUNCTURE_5_6 = np.array([[1, 1, 0, 1, 0], [1, 0, 1, 0, 1]])

#: WIMAX-style turbo puncturing over the [systematic, parity1, parity2]
#: streams: keep every systematic bit, alternate the parities -> rate 1/2
#: from the rate-1/3 mother turbo code.
PUNCTURE_TURBO_1_2 = np.array([[1, 1], [1, 0], [0, 1]])


def puncture(code: ConvCode, coded_bits: jnp.ndarray, pattern: np.ndarray
             ) -> jnp.ndarray:
    """Apply a puncture mask.  coded_bits: (..., T, n_out) -> masked flat
    stream is what a transmitter would send; here we return the (…, T,
    n_out) array with punctured positions REMOVED semantics left to the
    receiver by carrying the mask (see depuncture_metrics)."""
    T = coded_bits.shape[-2]
    mask = pattern_mask(code, T, pattern)
    return coded_bits * mask  # punctured positions zeroed (not transmitted)


def pattern_mask(code, T: int, pattern: np.ndarray) -> jnp.ndarray:
    """(T, n_out) 0/1 mask from a (n_out, period) pattern.

    ``code`` is anything with an ``n_out`` (ConvCode, RSCCode) or a bare int
    stream count — the turbo specs mask 1 + 2*n_parity streams, which belong
    to no single trellis.
    """
    n_out = code if isinstance(code, int) else code.n_out
    n, period = pattern.shape
    assert n == n_out, (n, n_out)
    reps = -(-T // period)
    mask = np.tile(pattern.T, (reps, 1))[:T]  # (T, n_out)
    return jnp.asarray(mask, jnp.float32)


def punctured_hard_metrics(code: ConvCode, received_bits: jnp.ndarray,
                           pattern: np.ndarray) -> jnp.ndarray:
    """Hamming branch metrics with punctured positions as erasures.

    received_bits: (..., T, n_out) where punctured positions are arbitrary.
    Returns (..., T, n_symbols): per-symbol distance counting ONLY
    transmitted positions.
    """
    T = received_bits.shape[-2]
    mask = pattern_mask(code, T, pattern)  # (T, n)
    bits = jnp.asarray(code.symbol_bits)  # (M, n)
    r = received_bits.astype(jnp.float32)[..., None, :]  # (..., T, 1, n)
    diff = jnp.abs(r - bits[None, :, :])  # (..., T, M, n)
    return (diff * mask[:, None, :]).sum(-1)


def effective_rate(code: ConvCode, pattern: np.ndarray) -> float:
    """k/n after puncturing: period input bits -> surviving coded bits."""
    period = pattern.shape[1]
    return period / float(pattern.sum())
