"""Linear-chain CRF — the paper's trellis machinery as a *trainable*
structured-prediction head.

The Viterbi ACS step is a product in the (max,+) semiring; swapping the
semiring to (logsumexp,+) gives the CRF forward algorithm (partition
function), and the gradient of log Z recovers marginals — so one trellis
implementation serves decoding (the paper's use) and learning.  Decode
reuses :func:`repro.core.viterbi.hmm_viterbi`; training uses the
forward-backward identity  log p(y|x) = score(x,y) − log Z(x).

Both the sequential scan and a log-depth associative-scan variant of the
forward pass are provided — the same parallelization the (min,+) decoder
uses, because (logsumexp,+) matrix products are associative too.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.viterbi import hmm_viterbi


def crf_score(transitions: jnp.ndarray, emissions: jnp.ndarray,
              tags: jnp.ndarray) -> jnp.ndarray:
    """Unnormalized path score.  transitions: (S, S) [from, to];
    emissions: (B, T, S); tags: (B, T) int32.  Returns (B,)."""
    B, T, S = emissions.shape
    em = jnp.take_along_axis(emissions, tags[..., None], axis=-1)[..., 0]
    tr = transitions[tags[:, :-1], tags[:, 1:]]
    return em.sum(-1) + tr.sum(-1)


def crf_log_norm(transitions: jnp.ndarray, emissions: jnp.ndarray,
                 parallel: bool = False) -> jnp.ndarray:
    """log Z via the forward algorithm in the (logsumexp,+) semiring."""
    B, T, S = emissions.shape
    alpha0 = emissions[:, 0]  # (B, S)

    if not parallel:
        def step(alpha, em_t):
            nxt = jax.nn.logsumexp(
                alpha[:, :, None] + transitions[None], axis=1) + em_t
            return nxt, None

        alpha, _ = jax.lax.scan(step, alpha0, emissions[:, 1:].swapaxes(0, 1))
        return jax.nn.logsumexp(alpha, axis=-1)

    # log-depth: (logsumexp,+) matrix product associative scan (the same
    # trick as viterbi_decode_parallel with a different semiring)
    mats = transitions[None, None] + emissions[:, 1:, None, :]  # (B,T-1,S,S)

    def lse_matmul(a, b):
        return jax.nn.logsumexp(a[..., :, :, None] + b[..., None, :, :], axis=-2)

    prefix = jax.lax.associative_scan(lse_matmul, mats, axis=1)
    total = prefix[:, -1]  # (B, S, S)
    return jax.nn.logsumexp(alpha0[:, :, None] + total, axis=(1, 2))


def crf_loss(transitions, emissions, tags, valid: Optional[jnp.ndarray] = None
             ) -> jnp.ndarray:
    """Mean negative log-likelihood (full-length sequences)."""
    nll = crf_log_norm(transitions, emissions) - crf_score(
        transitions, emissions, tags)
    return nll.mean()


def crf_decode(transitions, emissions) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """MAP tag sequence = Viterbi in the (max,+) semiring (the paper's
    decoder, with learned scores).  Returns (tags (B,T), score (B,))."""
    B, T, S = emissions.shape
    states, score = hmm_viterbi(
        transitions, emissions, log_init=jnp.zeros((S,)))
    return states, score


def crf_marginals(transitions, emissions) -> jnp.ndarray:
    """Posterior tag marginals via autodiff: d logZ / d emissions."""
    def logz(em):
        return crf_log_norm(transitions, em).sum()

    return jax.grad(logz)(emissions)
