"""Channel models + branch-metric table construction (hard & soft decision)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.trellis import ConvCode


def bsc(key: jax.Array, coded_bits: jnp.ndarray, flip_prob: float) -> jnp.ndarray:
    """Binary symmetric channel: flip each bit with probability ``flip_prob``."""
    flips = jax.random.bernoulli(key, flip_prob, coded_bits.shape)
    return (coded_bits.astype(jnp.int32) ^ flips.astype(jnp.int32)).astype(jnp.int32)


def bpsk_modulate(coded_bits: jnp.ndarray) -> jnp.ndarray:
    """Map bit {0,1} -> symbol {+1,-1}."""
    return 1.0 - 2.0 * coded_bits.astype(jnp.float32)


def awgn(key: jax.Array, symbols: jnp.ndarray, snr_db: float) -> jnp.ndarray:
    """Add white Gaussian noise at the given Es/N0 (dB); unit symbol energy."""
    snr = 10.0 ** (snr_db / 10.0)
    sigma = jnp.sqrt(1.0 / (2.0 * snr))
    return symbols + sigma * jax.random.normal(key, symbols.shape)


def hard_branch_metrics(code: ConvCode, received_bits: jnp.ndarray) -> jnp.ndarray:
    """Hamming branch-metric tables.

    Args:
      received_bits: (..., T, n_out) hard bits.
    Returns:
      (..., T, n_symbols) float32 where entry c = hamming(r_t, symbol c).
    """
    from repro.core.encoder import pack_symbols

    r = pack_symbols(code, received_bits)  # (..., T)
    table = jnp.asarray(code.hamming_table)  # (M, M)
    return table[r]  # (..., T, M)


def soft_branch_metrics(code: ConvCode, received_values: jnp.ndarray) -> jnp.ndarray:
    """Soft (correlation) branch-metric tables, to be MINIMIZED.

    For BPSK (bit b -> symbol 1-2b) the ML metric to minimize is
    ``sum_j (y_j - x_j)^2``;  dropping terms constant across symbols leaves
    ``-2 sum_j y_j x_j``, i.e. ``bm(c) = sum_j y_j * (2*bit_j(c) - 1)``.

    Args:
      received_values: (..., T, n_out) real channel outputs.
    Returns:
      (..., T, n_symbols) float32.
    """
    bits = jnp.asarray(code.symbol_bits)  # (M, n)
    x = 2.0 * bits - 1.0  # (M, n): +1 for bit 1 ... sign such that minimizing works
    return jnp.einsum("...tj,mj->...tm", received_values.astype(jnp.float32), x)
