"""Viterbi decoders: sequential scan, block-parallel (min,+) associative scan,
and general HMM max-sum Viterbi.

All decoders consume *branch-metric tables* (see channel.py) so that hard and
soft decision decoding share one code path — exactly how the paper's Texpand
instruction is fed precomputed branch metrics.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.acs import acs_step
from repro.core.trellis import NEG_UNREACHABLE, ConvCode

BIG = jnp.float32(NEG_UNREACHABLE)


def _initial_pm(code: ConvCode, batch_shape) -> jnp.ndarray:
    """Paths start in state 0 (paper §IV-B)."""
    pm0 = jnp.full(batch_shape + (code.n_states,), BIG, dtype=jnp.float32)
    return pm0.at[..., 0].set(0.0)


def _traceback(code: ConvCode, bps: jnp.ndarray, final_state: jnp.ndarray):
    """Trace back through backpointers.

    Args:
      bps: (T, B, S) int32 backpointer parity bits.
      final_state: (B,) int32.
    Returns:
      bits: (B, T) decoded input bits (newest convention: u_t = MSB of s_t).
      states: (B, T) the surviving state sequence s_1..s_T.
    """
    K = code.constraint
    half = code.n_states // 2

    def step(s, bp_t):
        u = s >> (K - 2)  # input bit that produced s
        v = s & (half - 1) if half > 1 else jnp.zeros_like(s)
        j = jnp.take_along_axis(bp_t, s[:, None], axis=-1)[:, 0]
        prev = 2 * v + j
        return prev, (u, s)

    _, (bits_rev, states_rev) = jax.lax.scan(step, final_state, bps, reverse=True)
    return bits_rev.T, states_rev.T  # (B, T)


def viterbi_decode(
    code: ConvCode,
    bm_tables: jnp.ndarray,
    terminated: bool = True,
    normalize: bool = False,
    unroll: int = 1,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sequential-scan Viterbi decoder (the faithful baseline).

    Args:
      bm_tables: (B, T, n_symbols) float32 branch-metric tables (minimize).
      terminated: trellis ends in state 0 (flush bits appended at encode).
      normalize: subtract the per-step min from the path metrics (needed only
        for extremely long streams to bound metric growth).
      unroll: scan unroll factor (perf knob).

    Returns:
      bits: (B, T) decoded input bits (including flush bits if terminated).
      metric: (B,) the winning path metric.
    """
    B, T, M = bm_tables.shape
    pm0 = _initial_pm(code, (B,))

    def step(pm, bm_t):
        new_pm, bp = acs_step(code, pm, bm_t)
        if normalize:
            new_pm = new_pm - new_pm.min(axis=-1, keepdims=True)
        return new_pm, bp

    pm, bps = jax.lax.scan(step, pm0, bm_tables.swapaxes(0, 1), unroll=unroll)
    if terminated:
        final_state = jnp.zeros((B,), dtype=jnp.int32)
        metric = pm[..., 0]
    else:
        final_state = jnp.argmin(pm, axis=-1).astype(jnp.int32)
        metric = pm.min(axis=-1)
    bits, _ = _traceback(code, bps, final_state)
    return bits, metric


# --------------------------------------------------------------------------- #
# Block-parallel decoder: (min,+) semiring associative scan.                   #
# Beyond-paper: log-depth in the number of chunks -> sequence-parallelizable.  #
# --------------------------------------------------------------------------- #


def minplus_matmul(A: jnp.ndarray, B_: jnp.ndarray) -> jnp.ndarray:
    """C[i,j] = min_k A[i,k] + B[k,j] over the last two axes (batched)."""
    return jnp.min(A[..., :, :, None] + B_[..., None, :, :], axis=-2)


def _chunk_transfer_matrices(code: ConvCode, bm_chunks: jnp.ndarray) -> jnp.ndarray:
    """Transfer matrix of each chunk.

    Args:
      bm_chunks: (B, nc, C, M).
    Returns:
      (B, nc, S, S): entry [i, s] = best metric from state i (chunk entry) to
      state s (chunk exit).
    """
    S = code.n_states

    def one_chunk(bm_chunk):  # (C, M)
        pm0 = jnp.where(jnp.eye(S, dtype=bool), 0.0, BIG)  # (S, S) identity

        def step(pm, bm_t):
            # rows are independent initial states: ACS applied per row, with a
            # broadcast branch-metric table.
            new_pm, _ = acs_step(code, pm, jnp.broadcast_to(bm_t, (S,) + bm_t.shape))
            # clamp so BIG never exceeds float range after repeated adds
            return jnp.minimum(new_pm, BIG), None

        pm, _ = jax.lax.scan(step, pm0, bm_chunk)
        return pm

    return jax.vmap(jax.vmap(one_chunk))(bm_chunks)


def viterbi_decode_parallel(
    code: ConvCode,
    bm_tables: jnp.ndarray,
    chunk: int = 64,
    terminated: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Block-parallel Viterbi: chunk transfer matrices + associative (min,+)
    scan over chunks + per-chunk parallel re-scan for backpointers.

    Matches :func:`viterbi_decode` exactly on the winning metric, and on the
    decoded bits whenever the optimum is unique (the paper's tie-break is
    preserved within chunks; across chunks ties resolve identically because
    the boundary metrics coincide).
    """
    B, T, M = bm_tables.shape
    S = code.n_states
    pad = (-T) % chunk
    if pad:
        # identity steps: emitted as identity transfer matrices below.
        bm_tables = jnp.pad(bm_tables, ((0, 0), (0, pad), (0, 0)))
    Tp = T + pad
    nc = Tp // chunk
    bm_chunks = bm_tables.reshape(B, nc, chunk, M)

    mats = _chunk_transfer_matrices(code, bm_chunks)  # (B, nc, S, S)
    if pad:
        # replace the padded tail's contribution inside the last chunk by
        # recomputing it on the unpadded remainder handled via masking: the
        # padded steps used bm=0 tables which are NOT identity; fix by
        # computing the last chunk's matrix from the valid prefix only.
        valid = T - (nc - 1) * chunk

        def last_chunk_mat(bm_chunk):  # (chunk, M)
            pm0 = jnp.where(jnp.eye(S, dtype=bool), 0.0, BIG)

            def step(carry, xs):
                pm = carry
                bm_t, t = xs
                new_pm, _ = acs_step(code, pm, jnp.broadcast_to(bm_t, (S,) + bm_t.shape))
                new_pm = jnp.minimum(new_pm, BIG)
                return jnp.where(t < valid, new_pm, pm), None

            pm, _ = jax.lax.scan(step, pm0, (bm_chunk, jnp.arange(chunk)))
            return pm

        mats = mats.at[:, -1].set(jax.vmap(last_chunk_mat)(bm_chunks[:, -1]))

    # log-depth prefix products over chunks
    prefixes = jax.lax.associative_scan(minplus_matmul, mats, axis=1)  # (B, nc, S, S)
    eye = jnp.where(jnp.eye(S, dtype=bool), 0.0, BIG)
    excl = jnp.concatenate(
        [jnp.broadcast_to(eye, (B, 1, S, S)), prefixes[:, :-1]], axis=1
    )  # exclusive prefixes
    # boundary path metrics entering each chunk, starting from state 0
    boundary_pm = excl[:, :, 0, :]  # (B, nc, S)

    # re-scan each chunk (all chunks in parallel) to recover backpointers
    def chunk_scan(pm0, bm_chunk):  # (S,), (chunk, M)
        def step(pm, bm_t):
            new_pm, bp = acs_step(code, pm, bm_t)
            return jnp.minimum(new_pm, BIG), bp

        pm, bps = jax.lax.scan(step, pm0, bm_chunk)
        return pm, bps

    _, bps = jax.vmap(jax.vmap(chunk_scan))(boundary_pm, bm_chunks)  # (B, nc, chunk, S)
    bps = bps.reshape(B, Tp, S).swapaxes(0, 1)[:T]  # (T, B, S)

    final_pm = prefixes[:, -1, 0, :]  # (B, S) metrics from state 0 over full T
    if terminated:
        final_state = jnp.zeros((B,), dtype=jnp.int32)
        metric = final_pm[:, 0]
    else:
        final_state = jnp.argmin(final_pm, axis=-1).astype(jnp.int32)
        metric = final_pm.min(axis=-1)
    bits, _ = _traceback(code, bps, final_state)
    return bits, metric


# --------------------------------------------------------------------------- #
# General HMM max-sum Viterbi (the technique generalized beyond conv codes).   #
# --------------------------------------------------------------------------- #


def hmm_viterbi(
    log_trans: jnp.ndarray,
    log_emit: jnp.ndarray,
    log_init: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Most-likely state sequence of an HMM (max-sum Viterbi).

    Args:
      log_trans: (S, S) log transition matrix [from, to].
      log_emit: (B, T, S) log emission scores.
      log_init: (S,) log initial distribution (default: uniform).

    Returns:
      states: (B, T) argmax state path; loglik: (B,).
    """
    B, T, S = log_emit.shape
    if log_init is None:
        log_init = jnp.zeros((S,)) - jnp.log(S)
    delta0 = log_init[None, :] + log_emit[:, 0, :]  # (B, S)

    def step(delta, em_t):
        cand = delta[:, :, None] + log_trans[None]  # (B, S_from, S_to)
        bp = jnp.argmax(cand, axis=1).astype(jnp.int32)  # ties -> lowest state
        new = jnp.max(cand, axis=1) + em_t
        return new, bp

    delta, bps = jax.lax.scan(step, delta0, log_emit[:, 1:].swapaxes(0, 1))

    final = jnp.argmax(delta, axis=-1).astype(jnp.int32)
    loglik = jnp.max(delta, axis=-1)

    def back(s, bp_t):
        prev = jnp.take_along_axis(bp_t, s[:, None], axis=-1)[:, 0]
        return prev, s

    first, states_rev = jax.lax.scan(back, final, bps, reverse=True)
    states = jnp.concatenate([first[:, None], states_rev.T], axis=1)  # (B, T)
    return states, loglik
