"""Core library: the paper's contribution (Viterbi / trellis ACS) in JAX."""
from repro.core.crf import crf_decode, crf_log_norm, crf_loss, crf_marginals, crf_score
from repro.core.puncture import (
    PUNCTURE_2_3,
    PUNCTURE_3_4,
    PUNCTURE_5_6,
    effective_rate,
    punctured_hard_metrics,
)
from repro.core.acs import acs_step, acs_step_unfused
from repro.core.channel import (
    awgn,
    bpsk_modulate,
    bsc,
    hard_branch_metrics,
    soft_branch_metrics,
)
from repro.core.encoder import encode, pack_symbols, unpack_symbols
from repro.core.trellis import (
    CODE_K3_PAPER,
    CODE_K3_STD,
    CODE_K5_GSM,
    CODE_K7_NASA,
    ConvCode,
    paper_expansion_calls,
)
from repro.core.viterbi import (
    hmm_viterbi,
    minplus_matmul,
    viterbi_decode,
    viterbi_decode_parallel,
)

__all__ = [
    "acs_step",
    "acs_step_unfused",
    "awgn",
    "bpsk_modulate",
    "bsc",
    "hard_branch_metrics",
    "soft_branch_metrics",
    "encode",
    "pack_symbols",
    "unpack_symbols",
    "CODE_K3_PAPER",
    "CODE_K3_STD",
    "CODE_K5_GSM",
    "CODE_K7_NASA",
    "ConvCode",
    "paper_expansion_calls",
    "hmm_viterbi",
    "viterbi_decode",
    "viterbi_decode_parallel",
    "minplus_matmul",
]
