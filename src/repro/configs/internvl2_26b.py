"""internvl2-26b [arXiv:2404.16821]: InternViT frontend (STUB per assignment:
input_specs provides precomputed patch embeddings, frontend_dim=3200) +
InternLM2-20B backbone: 48L d=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.
256 patch tokens are prefixed inside the sequence."""
from repro.configs.base import ArchBundle, ModelConfig, PartitionConfig

ARCH = ArchBundle(
    model=ModelConfig(
        name="internvl2-26b",
        n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
        d_ff=16384, vocab=92672,  # 92553 padded to 256-mult (TP-shardable; Megatron-style)
        pattern=(("attn", "mlp"),),
        rope_theta=1e6,
        modality="vision", frontend_dim=3200, n_prefix_tokens=256,
    ),
    partition=PartitionConfig(remat="full", fsdp=True, microbatches=4),
    skip_shapes=(("long_500k", "pure full-attention arch (see DESIGN.md)"),),
)

SMOKE = ArchBundle(
    model=ModelConfig(
        name="internvl2-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512,
        pattern=(("attn", "mlp"),),
        rope_theta=1e4,
        modality="vision", frontend_dim=48, n_prefix_tokens=8,
    ),
    partition=PartitionConfig(remat="none", attn_chunk_q=32, attn_chunk_kv=32),
)
