"""Config system: architecture, shapes, partitioning, run options.

Every assigned architecture gets one file in this package exporting
``ARCH: ArchBundle``.  ``registry()`` collects them for ``--arch`` lookup.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

# ---------------------------------------------------------------------------- #
# Architecture                                                                  #
# ---------------------------------------------------------------------------- #

# mixer kinds: attn (causal full), attn_bidir, attn_local (sliding window),
#              mla (deepseek multi-head latent attention), mamba, mlstm, slstm
# ffn kinds:   mlp, moe, none


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    d_expert: int = 0  # expert hidden size (d_ff of each expert)
    n_shared: int = 0  # shared (always-on) experts, deepseek-style
    renormalize: bool = True  # renormalize top-k gates to sum 1
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 0  # 0 = no q compression (v2-lite)
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model/16)
    chunk: int = 256  # selective-scan chunk length


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0
    chunk: int = 256  # mLSTM chunkwise-parallel chunk length
    conv_kernel: int = 4


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str = "lm"  # lm | encdec
    n_layers: int = 12
    d_model: int = 1024
    n_heads: int = 16
    n_kv_heads: int = 16
    head_dim: int = 0  # 0 -> d_model // n_heads
    d_ff: int = 4096
    vocab: int = 32000
    # repeating layer group: tuple of (mixer, ffn); len must divide n_layers
    pattern: Tuple[Tuple[str, str], ...] = (("attn", "mlp"),)
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    # attention details
    rope_theta: float = 1e4
    rope_local_theta: float = 1e4  # theta for attn_local layers (gemma3 10k/1M split)
    window: int = 1024  # sliding window for attn_local
    qk_norm: bool = False
    qkv_bias: bool = False
    logit_softcap: float = 0.0
    # embeddings / norms
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    norm_style: str = "pre"  # pre | sandwich (gemma3)
    act: str = "silu"  # silu | gelu
    embed_scale: bool = False  # gemma-style sqrt(d) embedding scaling
    # encoder (family == encdec): encoder reuses d_model/heads/ff unless set
    enc_layers: int = 0
    enc_pattern: Tuple[Tuple[str, str], ...] = (("attn_bidir", "mlp"),)
    dec_ratio: int = 4  # train: decoder seq = seq // dec_ratio for encdec
    # multimodal frontend stub
    modality: Optional[str] = None  # vision | audio | None
    frontend_dim: int = 0  # dim of precomputed patch/frame embeddings
    n_prefix_tokens: int = 0  # vision: number of patch tokens inside seq
    # precision
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def group_size(self) -> int:
        return len(self.pattern)

    @property
    def n_groups(self) -> int:
        assert self.n_layers % self.group_size == 0, (self.name, self.n_layers, self.group_size)
        return self.n_layers // self.group_size

    def param_count(self) -> Dict[str, float]:
        """Analytic parameter counts: total and active (MoE-aware), in units
        of parameters.  Used for MODEL_FLOPS in the roofline report."""
        d, hd = self.d_model, self.resolved_head_dim
        counts = {"embed": self.vocab * d * (1 if self.tie_embeddings else 2)}
        total = 0.0
        active = 0.0
        for mixer, ffn in self.pattern:
            m_params = 0.0
            if mixer in ("attn", "attn_bidir", "attn_local"):
                q = d * self.n_heads * hd
                kv = 2 * d * self.n_kv_heads * hd
                o = self.n_heads * hd * d
                m_params = q + kv + o
                if mixer == "attn_bidir" and self.family == "encdec":
                    pass
            elif mixer == "mla":
                mla = self.mla
                qk_dim = mla.nope_head_dim + mla.rope_head_dim
                q = d * self.n_heads * qk_dim if not mla.q_lora_rank else (
                    d * mla.q_lora_rank + mla.q_lora_rank * self.n_heads * qk_dim)
                kv_down = d * (mla.kv_lora_rank + mla.rope_head_dim)
                k_up = mla.kv_lora_rank * self.n_heads * mla.nope_head_dim
                v_up = mla.kv_lora_rank * self.n_heads * mla.v_head_dim
                o = self.n_heads * mla.v_head_dim * d
                m_params = q + kv_down + k_up + v_up + o
            elif mixer == "mamba":
                s = self.ssm
                d_in = s.expand * d
                dt_rank = s.dt_rank or -(-d // 16)
                m_params = (d * 2 * d_in + d_in * s.d_conv + d_in * (dt_rank + 2 * s.d_state)
                            + dt_rank * d_in + d_in * s.d_state + d_in + d_in * d)
            elif mixer in ("mlstm", "slstm"):
                x = self.xlstm
                pf = x.mlstm_proj_factor if mixer == "mlstm" else x.slstm_proj_factor
                d_in = int(pf * d)
                # up/down proj + qkv/gates approx
                m_params = 2 * d * d_in + 4 * d_in * d_in // max(1, self.n_heads)
            f_params = 0.0
            f_active = 0.0
            if ffn == "mlp":
                f_params = 3 * d * self.d_ff
                f_active = f_params
            elif ffn == "moe":
                moe = self.moe
                e_ff = moe.d_expert or self.d_ff
                f_params = moe.n_experts * 3 * d * e_ff + moe.n_shared * 3 * d * e_ff
                f_params += d * moe.n_experts  # router
                f_active = (moe.top_k + moe.n_shared) * 3 * d * e_ff + d * moe.n_experts
            total += (m_params + f_params) * self.n_groups
            active += (m_params + (f_active or f_params)) * self.n_groups
        if self.family == "encdec":
            # encoder layers + decoder cross-attention
            enc = self.enc_layers * (4 * d * self.n_heads * hd + 3 * d * self.d_ff)
            cross = self.n_layers * (4 * d * self.n_heads * hd)
            total += enc + cross
            active += enc + cross
        counts["total"] = total + counts["embed"]
        counts["active"] = active + counts["embed"]
        return counts


# ---------------------------------------------------------------------------- #
# Shapes (assigned): every LM arch gets these four cells.                       #
# ---------------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------- #
# Partitioning / run options                                                    #
# ---------------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class PartitionConfig:
    fsdp: bool = False  # shard params/optimizer over the data axis too (ZeRO-3)
    zero_stage: int = 3  # with fsdp: 3 = params+opt sharded over data;
    #                      1 = opt state only (params replicated on data:
    #                      no per-layer weight all-gather, one at update)
    seq_shard_activations: bool = False  # Megatron-SP residual sharding
    flash_decode: bool = True  # shard_map seq-sharded decode attention
    remat: str = "full"  # full | dots | none
    microbatches: int = 1  # gradient-accumulation chunks per step
    scan_layers: bool = True
    attn_chunk_q: int = 2048
    attn_chunk_kv: int = 2048
    grad_reduce: str = "allreduce"  # allreduce | reduce_scatter (ZeRO-1/2 style)
    optimizer: str = "adamw"  # adamw | adafactor


@dataclasses.dataclass(frozen=True)
class ArchBundle:
    model: ModelConfig
    partition: PartitionConfig = PartitionConfig()
    # cells where this arch skips a shape, with reason (DESIGN.md table)
    skip_shapes: Tuple[Tuple[str, str], ...] = ()

    def skips(self, shape_name: str) -> Optional[str]:
        for s, why in self.skip_shapes:
            if s == shape_name:
                return why
        return None


_ARCH_IDS = (
    "qwen3_moe_30b_a3b",
    "deepseek_v2_lite_16b",
    "xlstm_350m",
    "qwen1_5_110b",
    "qwen3_4b",
    "gemma3_12b",
    "qwen2_5_3b",
    "internvl2_26b",
    "seamless_m4t_large_v2",
    "jamba_v0_1_52b",
)


def arch_ids() -> Tuple[str, ...]:
    return _ARCH_IDS


def get_arch(arch_id: str) -> ArchBundle:
    arch_id = arch_id.replace("-", "_").replace(".", "_")
    if arch_id not in _ARCH_IDS and arch_id != "paper_viterbi":
        raise KeyError(f"unknown arch '{arch_id}'; known: {_ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.ARCH


def get_smoke_arch(arch_id: str) -> ArchBundle:
    """Reduced same-family config for CPU smoke tests."""
    arch_id = arch_id.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.SMOKE
