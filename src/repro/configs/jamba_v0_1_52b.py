"""jamba-v0.1-52b [arXiv:2403.19887]: 32L d=4096 32H (GQA kv=8) d_ff=14336
vocab=65536; Jamba block = 8 layers with 1 attention : 7 Mamba and MoE every
other layer (16 experts top-2)."""
from repro.configs.base import ArchBundle, MoEConfig, ModelConfig, PartitionConfig, SSMConfig

_PATTERN = (
    ("mamba", "mlp"), ("mamba", "moe"), ("mamba", "mlp"), ("mamba", "moe"),
    ("attn", "mlp"), ("mamba", "moe"), ("mamba", "mlp"), ("mamba", "moe"),
)

ARCH = ArchBundle(
    model=ModelConfig(
        name="jamba-v0.1-52b",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab=65536,
        pattern=_PATTERN,
        moe=MoEConfig(n_experts=16, top_k=2, d_expert=14336),
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, chunk=256),
        rope_theta=1e6,
    ),
    partition=PartitionConfig(remat="full", fsdp=True, microbatches=8),
    # long_500k runs: 28/32 layers are Mamba (O(1) state); the 4 attention
    # layers use seq-sharded flash decode over the 500k cache.
)

SMOKE = ArchBundle(
    model=ModelConfig(
        name="jamba-smoke",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512,
        pattern=(("mamba", "mlp"), ("mamba", "moe"), ("attn", "mlp"), ("mamba", "moe")),
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=64),
        ssm=SSMConfig(d_state=8, d_conv=4, expand=2, chunk=16),
        rope_theta=1e4,
    ),
    partition=PartitionConfig(remat="none", attn_chunk_q=32, attn_chunk_kv=32),
)
