"""The paper's own workload: Viterbi decoding of rate-1/2 convolutional
codes.  Not an LM — this config names the trellis codes and batch shapes the
benchmarks/examples use, mirroring the paper's 12..60-bit sweeps (Fig. 3)
plus throughput-scale batches for the TPU analogue."""
import dataclasses
from typing import Optional, Tuple

from repro.core.trellis import CODE_K3_PAPER, CODE_K3_STD, CODE_K5_GSM, CODE_K7_NASA, ConvCode
from repro.decode.spec import CodecSpec


@dataclasses.dataclass(frozen=True)
class ViterbiShape:
    name: str
    n_info_bits: int  # information bits per stream (before flush)
    batch: int


@dataclasses.dataclass(frozen=True)
class ViterbiBundle:
    code: ConvCode = CODE_K3_STD
    paper_code: ConvCode = CODE_K3_PAPER
    shapes: Tuple[ViterbiShape, ...] = (
        # the paper's Fig. 3 sweep: 12..60 coded bits (= 6..30 info bits at
        # rate 1/2, including the 2 flush bits for K=3)
        ViterbiShape("paper_12b", 4, 1),
        ViterbiShape("paper_24b", 10, 1),
        ViterbiShape("paper_36b", 16, 1),
        ViterbiShape("paper_48b", 22, 1),
        ViterbiShape("paper_60b", 28, 1),
        # TPU-scale throughput shapes (batch rides the 128-lane axis)
        ViterbiShape("tpu_gsm_burst", 185, 4096),   # GSM full-rate burst, K=5
        ViterbiShape("tpu_nasa_frame", 1024, 1024),  # NASA K=7 frames
        ViterbiShape("tpu_stream_64k", 65536, 128),  # long-stream decode
    )


ARCH = ViterbiBundle()
SMOKE = ViterbiBundle(shapes=(ViterbiShape("smoke", 16, 8),))

CODES = {
    "k3_std": CODE_K3_STD,
    "k3_paper": CODE_K3_PAPER,
    "k5_gsm": CODE_K5_GSM,
    "k7_nasa": CODE_K7_NASA,
}

# ---------------------------------------------------------------------------- #
# The ONE decode configuration examples and benchmarks share: codec specs for  #
# the paper workload and the streaming-subsystem shape defaults.  Example and  #
# benchmark scripts must source these instead of re-stating literals.          #
# ---------------------------------------------------------------------------- #

#: Hard-decision rate-1/2 K=3 spec — the paper's baseline workload.
DECODE_SPEC = CodecSpec(code=CODE_K3_STD, metric="hard")
#: Soft-decision variant of the same code (BPSK + AWGN channels).
DECODE_SPEC_SOFT = CodecSpec(code=CODE_K3_STD, metric="soft")

#: LM-source demos pack tokens from a 512-word vocab into 9-bit symbols.
SERVE_BITS_PER_TOKEN = 9


@dataclasses.dataclass(frozen=True)
class StreamDefaults:
    """Shared shape defaults for the streaming subsystem (sessions,
    scheduler, stream benchmarks): chunk per tick, the continuous-batching
    decode-block size, and the mesh axis a sharded scheduler spans.

    ``n_slots`` is the PER-SHARD slot load: a sharded scheduler weak-scales,
    so the slot table grows with the mesh (``n_slots_for``) and each device
    carries the same number of slots a single-device scheduler would.

    ``max_buffered`` is the per-stream input-queue bound for online
    ingestion (unconsumed rows a chunk-fed stream may hold before
    ``submit_chunk`` raises StreamBusy): 8 chunks — deep enough to ride out
    tick jitter, shallow enough that backpressure reaches the source within
    one window's worth of symbols."""

    chunk: int = 64
    n_slots: int = 64
    max_buffered: int = 512  # 8 * chunk
    mesh_axis: str = "data"

    def depth(self, code: ConvCode) -> int:
        """The subsystem's single depth rule (stream.window.default_depth)."""
        from repro.stream.window import default_depth

        return default_depth(code)

    def n_slots_for(self, n_shards: int, slots_per_shard: Optional[int] = None) -> int:
        """Weak-scaling slot-table size: per-shard load (default
        ``self.n_slots``) times shard count — the one sizing rule the
        sharded stream benchmark and deployments share."""
        per_shard = self.n_slots if slots_per_shard is None else slots_per_shard
        return per_shard * max(1, int(n_shards))


STREAM = StreamDefaults()
