"""deepseek-v2-lite-16b [arXiv:2405.04434]: 27L d=2048 16H, MLA kv_lora=512
(rope 64 / nope 128 / v 128), MoE 64 routed top-6 + 2 shared, expert
d_ff=1408, vocab 102400.  (The real model's dense first layer is simplified
to a uniform MoE stack — noted in DESIGN.md §Arch-applicability.)"""
from repro.configs.base import ArchBundle, MLAConfig, MoEConfig, ModelConfig, PartitionConfig

ARCH = ArchBundle(
    model=ModelConfig(
        name="deepseek-v2-lite-16b",
        n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=192,
        d_ff=1408, vocab=102400,
        pattern=(("mla", "moe"),),
        mla=MLAConfig(kv_lora_rank=512, rope_head_dim=64, nope_head_dim=128,
                      v_head_dim=128),
        moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, n_shared=2),
        rope_theta=1e4,
    ),
    partition=PartitionConfig(remat="full", fsdp=True, microbatches=4),
    skip_shapes=(("long_500k", "MLA is full attention over compressed KV"),),
)

SMOKE = ArchBundle(
    model=ModelConfig(
        name="deepseek-v2-lite-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=24,
        d_ff=32, vocab=512,
        pattern=(("mla", "moe"),),
        mla=MLAConfig(kv_lora_rank=32, rope_head_dim=8, nope_head_dim=16,
                      v_head_dim=16),
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=32, n_shared=1),
        rope_theta=1e4,
    ),
    partition=PartitionConfig(remat="none", attn_chunk_q=32, attn_chunk_kv=32),
)
