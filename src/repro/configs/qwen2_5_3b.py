"""qwen2.5-3b [hf:Qwen/Qwen2.5-*]: dense 36L d=2048 16H (GQA kv=2)
d_ff=11008 vocab=151936, QKV bias, tied embeddings."""
from repro.configs.base import ArchBundle, ModelConfig, PartitionConfig

ARCH = ArchBundle(
    model=ModelConfig(
        name="qwen2.5-3b",
        n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2, head_dim=128,
        d_ff=11008, vocab=151936,
        pattern=(("attn", "mlp"),),
        rope_theta=1e6, qkv_bias=True, tie_embeddings=True,
    ),
    partition=PartitionConfig(remat="full"),
    skip_shapes=(("long_500k", "pure full-attention arch (see DESIGN.md)"),),
)

SMOKE = ArchBundle(
    model=ModelConfig(
        name="qwen2.5-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512,
        pattern=(("attn", "mlp"),),
        rope_theta=1e4, qkv_bias=True, tie_embeddings=True,
    ),
    partition=PartitionConfig(remat="none", attn_chunk_q=32, attn_chunk_kv=32),
)
