"""qwen1.5-110b [hf:Qwen/Qwen1.5-*]: dense 80L d=8192 64H (GQA kv=8)
d_ff=49152 vocab=152064, QKV bias.  FSDP (ZeRO-3) sharding is on: params +
optimizer state shard over the data axis too — 110B fp32 params do not fit
replicated."""
from repro.configs.base import ArchBundle, ModelConfig, PartitionConfig

ARCH = ArchBundle(
    model=ModelConfig(
        name="qwen1.5-110b",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=49152, vocab=152064,
        pattern=(("attn", "mlp"),),
        rope_theta=1e6, qkv_bias=True,
    ),
    partition=PartitionConfig(remat="full", fsdp=True, optimizer="adafactor", microbatches=8),
    skip_shapes=(("long_500k", "pure full-attention arch (see DESIGN.md)"),),
)

SMOKE = ArchBundle(
    model=ModelConfig(
        name="qwen1.5-smoke",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab=512,
        pattern=(("attn", "mlp"),),
        rope_theta=1e4, qkv_bias=True,
    ),
    partition=PartitionConfig(remat="none", attn_chunk_q=32, attn_chunk_kv=32),
)
