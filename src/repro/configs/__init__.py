"""Architecture configs: one module per assigned architecture (+ the paper's
own Viterbi workload).  See base.py for the config dataclasses and registry."""
from repro.configs.base import (
    SHAPES,
    ArchBundle,
    ModelConfig,
    PartitionConfig,
    ShapeConfig,
    arch_ids,
    get_arch,
    get_smoke_arch,
)

__all__ = [
    "SHAPES",
    "ArchBundle",
    "ModelConfig",
    "PartitionConfig",
    "ShapeConfig",
    "arch_ids",
    "get_arch",
    "get_smoke_arch",
]
