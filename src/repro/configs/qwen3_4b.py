"""qwen3-4b [hf:Qwen/Qwen3-*]: dense 36L d=2560 32H (GQA kv=8) d_ff=9728
vocab=151936, qk-norm."""
from repro.configs.base import ArchBundle, ModelConfig, PartitionConfig

ARCH = ArchBundle(
    model=ModelConfig(
        name="qwen3-4b",
        n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=9728, vocab=151936,
        pattern=(("attn", "mlp"),),
        rope_theta=1e6, qk_norm=True,
    ),
    partition=PartitionConfig(remat="full", fsdp=True, microbatches=2),
    skip_shapes=(("long_500k", "pure full-attention arch (see DESIGN.md)"),),
)

SMOKE = ArchBundle(
    model=ModelConfig(
        name="qwen3-4b-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512,
        pattern=(("attn", "mlp"),),
        rope_theta=1e4, qk_norm=True,
    ),
    partition=PartitionConfig(remat="none", attn_chunk_q=32, attn_chunk_kv=32),
)
