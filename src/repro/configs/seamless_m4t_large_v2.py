"""seamless-m4t-large-v2 [arXiv:2308.11596]: encoder-decoder, 24L encoder +
24L decoder, d=1024 16H (kv=16) d_ff=8192 vocab=256206.  The audio frontend
is a STUB per the assignment: input_specs provides precomputed frame
embeddings (frontend_dim=1024).  Decoder seq = seq_len // dec_ratio at
train/prefill; decode runs one token against self + cross caches of
seq_len."""
from repro.configs.base import ArchBundle, ModelConfig, PartitionConfig

ARCH = ArchBundle(
    model=ModelConfig(
        name="seamless-m4t-large-v2",
        family="encdec",
        n_layers=24, enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
        head_dim=64, d_ff=8192, vocab=256256,  # 256206 padded to 256-mult (TP-shardable)
        pattern=(("attn", "mlp"),),
        rope_theta=1e4,
        modality="audio", frontend_dim=1024, dec_ratio=4,
    ),
    partition=PartitionConfig(remat="full"),
    skip_shapes=(("long_500k", "full-attention enc-dec (see DESIGN.md)"),),
)

SMOKE = ArchBundle(
    model=ModelConfig(
        name="seamless-smoke",
        family="encdec",
        n_layers=2, enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, vocab=512,
        pattern=(("attn", "mlp"),),
        rope_theta=1e4,
        modality="audio", frontend_dim=32, dec_ratio=4,
    ),
    partition=PartitionConfig(remat="none", attn_chunk_q=32, attn_chunk_kv=32),
)
