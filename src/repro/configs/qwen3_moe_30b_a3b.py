"""qwen3-moe-30b-a3b [hf:Qwen/Qwen3-30B-A3B]: 48L d=2048 32H (GQA kv=4)
MoE 128 experts top-8, expert d_ff=768, vocab 151936, qk-norm."""
from repro.configs.base import ArchBundle, MoEConfig, ModelConfig, PartitionConfig

ARCH = ArchBundle(
    model=ModelConfig(
        name="qwen3-moe-30b-a3b",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
        d_ff=768, vocab=151936,
        pattern=(("attn", "moe"),),
        moe=MoEConfig(n_experts=128, top_k=8, d_expert=768),
        rope_theta=1e6, qk_norm=True,
    ),
    partition=PartitionConfig(remat="full", fsdp=True, microbatches=4),
    skip_shapes=(("long_500k", "pure full-attention arch (see DESIGN.md)"),),
)

SMOKE = ArchBundle(
    model=ModelConfig(
        name="qwen3-moe-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=32, vocab=512,
        pattern=(("attn", "moe"),),
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=32),
        rope_theta=1e4, qk_norm=True,
    ),
    partition=PartitionConfig(remat="none", attn_chunk_q=32, attn_chunk_kv=32),
)
