"""gemma3-12b [hf:google/gemma-3-*]: 48L d=3840 16H (GQA kv=8) head_dim=256
d_ff=15360 vocab=262144; 5:1 local(window 1024):global attention, RoPE theta
10k local / 1M global, sandwich norms, tied embeddings with sqrt(d) scaling."""
from repro.configs.base import ArchBundle, ModelConfig, PartitionConfig

_PATTERN = tuple([("attn_local", "mlp")] * 5 + [("attn", "mlp")])

ARCH = ArchBundle(
    model=ModelConfig(
        name="gemma3-12b",
        n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8, head_dim=256,
        d_ff=15360, vocab=262144,
        pattern=_PATTERN,
        window=1024, rope_theta=1e6, rope_local_theta=1e4,
        qk_norm=True, norm_style="sandwich", act="gelu",
        tie_embeddings=True, embed_scale=True,
    ),
    partition=PartitionConfig(remat="full", fsdp=True, microbatches=8),
    # long_500k runs: 40/48 layers are window-1024; the 8 global layers use
    # seq-sharded flash decode over the 500k cache.
)

SMOKE = ArchBundle(
    model=ModelConfig(
        name="gemma3-smoke",
        n_layers=6, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512,
        pattern=_PATTERN,
        window=16, rope_theta=1e6, rope_local_theta=1e4,
        qk_norm=True, norm_style="sandwich", act="gelu",
        tie_embeddings=True, embed_scale=True,
    ),
    partition=PartitionConfig(remat="none", attn_chunk_q=32, attn_chunk_kv=32),
)
