"""xlstm-350m [arXiv:2405.04517]: 24L d=1024 4H, xLSTM[7:1] — groups of 8
blocks: 7 mLSTM + 1 sLSTM, no separate FFN (blocks carry their own
up/down projections), vocab 50304."""
from repro.configs.base import ArchBundle, ModelConfig, PartitionConfig, XLSTMConfig

_PATTERN = tuple([("mlstm", "none")] * 7 + [("slstm", "none")])

ARCH = ArchBundle(
    model=ModelConfig(
        name="xlstm-350m",
        n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab=50304,
        pattern=_PATTERN,
        xlstm=XLSTMConfig(mlstm_proj_factor=2.0, slstm_proj_factor=4.0 / 3.0,
                          chunk=256, conv_kernel=4),
        tie_embeddings=True,
    ),
    # microbatches=4: the sequential sLSTM/mLSTM recurrences are activation-
    # heavy per token; grad accumulation bounds per-chip live activations.
    partition=PartitionConfig(remat="full", microbatches=4),
)

SMOKE = ArchBundle(
    model=ModelConfig(
        name="xlstm-smoke",
        n_layers=4, d_model=64, n_heads=2, n_kv_heads=2,
        d_ff=0, vocab=512,
        pattern=(("mlstm", "none"), ("slstm", "none")),
        xlstm=XLSTMConfig(chunk=16),
        tie_embeddings=True,
    ),
    partition=PartitionConfig(remat="none"),
)
