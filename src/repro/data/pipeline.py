"""Data pipeline: deterministic, restart-safe synthetic streams.

Every batch is a pure function of (seed, step) — after a crash/restore the
loop replays exactly the batch it would have seen, with no iterator state to
checkpoint.  Per-host sharding: each host materializes only its slice of the
global batch (sliced by process_index; a single-process run owns everything).

Two generators:
  SyntheticLM     — Zipf-distributed token documents packed to seq_len with
                    EOS boundaries; labels = next token.
  ViterbiStream   — random information bits -> convolutional encode -> noisy
                    channel -> branch-metric tables (the paper's workload).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.channel import bsc, hard_branch_metrics
from repro.core.encoder import encode
from repro.core.trellis import ConvCode


@dataclasses.dataclass
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    eos: int = 0
    mean_doc_len: int = 512
    zipf_a: float = 1.2
    # modality stubs
    n_prefix_tokens: int = 0
    frontend_dim: int = 0
    family: str = "lm"
    dec_ratio: int = 4

    def host_batch(self) -> int:
        n_proc = jax.process_count()
        assert self.global_batch % n_proc == 0
        return self.global_batch // n_proc

    def __call__(self, step: int) -> Dict[str, jnp.ndarray]:
        # fold (seed, step, process) into one deterministic stream id
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, jax.process_index()]))
        B = self.host_batch()
        if self.family == "encdec":
            S_dec = self.seq_len // self.dec_ratio
            frames = rng.standard_normal(
                (B, self.seq_len, self.frontend_dim), dtype=np.float32)
            toks = self._pack_tokens(rng, B, S_dec + 1)
            return {
                "frames": jnp.asarray(frames, jnp.bfloat16),
                "tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(toks[:, 1:]),
            }
        S_tok = self.seq_len - self.n_prefix_tokens
        toks = self._pack_tokens(rng, B, S_tok + 1)
        out = {"tokens": jnp.asarray(toks[:, :-1]), "labels": jnp.asarray(toks[:, 1:])}
        if self.n_prefix_tokens:
            patches = rng.standard_normal(
                (B, self.n_prefix_tokens, self.frontend_dim), dtype=np.float32)
            out["patches"] = jnp.asarray(patches, jnp.bfloat16)
        return out

    def _pack_tokens(self, rng, B: int, S: int) -> np.ndarray:
        """Zipf tokens packed into documents separated by EOS."""
        toks = (rng.zipf(self.zipf_a, size=(B, S)) % (self.vocab - 1) + 1).astype(np.int32)
        # sprinkle EOS at ~1/mean_doc_len rate -> document boundaries
        eos_mask = rng.random((B, S)) < (1.0 / self.mean_doc_len)
        toks[eos_mask] = self.eos
        return toks


@dataclasses.dataclass
class ViterbiStream:
    """The paper's workload: coded bits over a noisy channel, batched."""

    code: ConvCode
    n_info_bits: int
    batch: int
    flip_prob: float = 0.02
    seed: int = 0

    def __call__(self, step: int):
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        k1, k2 = jax.random.split(key)
        bits = jax.random.bernoulli(k1, 0.5, (self.batch, self.n_info_bits)).astype(jnp.int32)
        coded = encode(self.code, bits, terminate=True)
        rx = bsc(k2, coded, self.flip_prob)
        bm = hard_branch_metrics(self.code, rx)
        return {"info_bits": bits, "coded": coded, "received": rx, "bm_tables": bm}


def make_data_iter(model, shape, seed: int = 0):
    """Data iterator factory keyed off a model config + shape cell."""
    cfg = model.cfg
    return SyntheticLM(
        vocab=cfg.vocab,
        seq_len=shape.seq_len,
        global_batch=shape.global_batch,
        seed=seed,
        n_prefix_tokens=cfg.n_prefix_tokens if cfg.modality == "vision" else 0,
        frontend_dim=cfg.frontend_dim,
        family=cfg.family,
        dec_ratio=cfg.dec_ratio,
    )
