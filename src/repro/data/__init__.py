from repro.data.pipeline import SyntheticLM, ViterbiStream, make_data_iter

__all__ = ["SyntheticLM", "ViterbiStream", "make_data_iter"]
